"""State-space / linear-recurrence blocks: RWKV-6 (Finch) and Mamba.

RWKV-6 is the attention-free arch (rwkv6-3b); Mamba heads run in parallel
with attention heads inside hymba layers.  Both are written as a `lax.scan`
recurrence (the paper-faithful baseline -- O(1) state, exact) plus, for
RWKV, a chunked MXU-friendly form used as a beyond-paper perf variant
(`rwkv_impl="chunked"`); the two are allclose-tested against each other.

Decode is a single recurrence step: state in, state out -- this is why the
ssm/hybrid archs are the ones that run the 500k-context cell.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import trunc_normal

__all__ = [
    "rwkv_params",
    "rwkv_train",
    "rwkv_decode",
    "rwkv_init_state",
    "mamba_params",
    "mamba_train",
    "mamba_decode",
    "mamba_init_state",
]

LORA_DECAY = 64
LORA_MIX = 32


# ---------------------------------------------------------------------------
# RWKV-6 ("Finch"): data-dependent decay linear attention
# ---------------------------------------------------------------------------


def rwkv_params(key, cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    H = cfg.n_rwkv_heads
    hd = D // H
    ks = jax.random.split(key, 16)
    dt = cfg.pdtype
    p = {
        # token-shift base mixes for r,k,v,w,g
        "mu": jnp.zeros((5, D), dt),
        # data-dependent decay lora: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.asarray(
            jnp.tile(jnp.linspace(-6.0, -1.0, hd), H), dt
        ),  # per-channel decay base, spread across the head dim
        "wA": trunc_normal(ks[0], (D, LORA_DECAY), 0.1, dt),
        "wB": trunc_normal(ks[1], (LORA_DECAY, D), 0.1, dt),
        "u": trunc_normal(ks[2], (D,), 1.0, dt),  # bonus for the current token
        "wr": trunc_normal(ks[3], (D, D), 1.0, dt),
        "wk": trunc_normal(ks[4], (D, D), 1.0, dt),
        "wv": trunc_normal(ks[5], (D, D), 1.0, dt),
        "wg": trunc_normal(ks[6], (D, D), 1.0, dt),
        "wo": trunc_normal(ks[7], (D, D), 1.0, dt),
        "gn_scale": jnp.ones((D,), dt),  # per-head group norm
    }
    return p


def _rwkv_inputs(p: Dict, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig):
    """Token shift + projections.  x: (B, S, D); x_prev: (B, 1, D) carry."""
    cd = cfg.cdtype
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xx = shifted - x
    mu = p["mu"].astype(cd)
    xr, xk, xv, xw, xg = (x + xx * mu[i] for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(cd))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(cd))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(cd))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(cd)).astype(jnp.float32))
    # data-dependent decay (f32 for stability)
    lora = jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["wA"].astype(cd))).astype(cd),
        p["wB"].astype(cd),
    )
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))  # < 0
    # clamp: keeps the chunked form's exp(-cum) factors inside f32 range
    # (chunk 16 * 4.0 << 88); w >= e^-4 per step is numerically indistinguishable
    logw = jnp.maximum(logw, -4.0)
    w = jnp.exp(logw)  # in (0, 1)
    return r, k, v, g, w, logw


def _heads(x: jax.Array, H: int) -> jax.Array:
    B, S, D = x.shape
    return x.reshape(B, S, H, D // H)


def _group_norm(o: jax.Array, scale: jax.Array, H: int, eps: float = 64e-5) -> jax.Array:
    """Per-head layer norm (RWKV's GroupNorm over heads)."""
    B, S, _, hd = o.shape
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + eps)
    return o.reshape(B, S, H * hd) * scale


def rwkv_init_state(cfg: ModelConfig, batch: int, layers: int) -> Dict:
    D = cfg.d_model
    H = cfg.n_rwkv_heads
    hd = D // H
    return {
        "wkv": jnp.zeros((layers, batch, H, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((layers, batch, 1, D), cfg.cdtype),  # time-mix shift
        "x_cm": jnp.zeros((layers, batch, 1, D), cfg.cdtype),  # channel-mix shift
    }


def _wkv_scan(r, k, v, w, u, state0):
    """Exact recurrence.  All (B, S, H, hd); state0 (B, H, hd, hd) f32.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    """

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)  # rank-1 update
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, o_t

    xs = tuple(jnp.moveaxis(a, 1, 0).astype(jnp.float32) for a in (r, k, v, w))
    S, os_ = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(os_, 0, 1), S  # (B, S, H, hd), final state


def _wkv_chunked(r, k, v, w, u, state0, chunk: int = 16):
    """Chunked parallel form (GLA-style): intra-chunk via masked matmuls on
    the MXU, inter-chunk via the carried state.  Matches _wkv_scan to ~1e-4.
    """
    B, S, H, hd = r.shape
    if S % chunk:
        pad = chunk - S % chunk
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = r.shape[1] // chunk
    rs = r.reshape(B, n, chunk, H, hd).astype(jnp.float32)
    ks = k.reshape(B, n, chunk, H, hd).astype(jnp.float32)
    vs = v.reshape(B, n, chunk, H, hd).astype(jnp.float32)
    ws = w.reshape(B, n, chunk, H, hd).astype(jnp.float32)
    logw = jnp.log(jnp.maximum(ws, 1e-38))
    cum = jnp.cumsum(logw, axis=2)  # log prod_{s<=t} w_s within chunk

    def chunk_step(S, inp):
        rc, kc, vc, cumc, logwc = inp  # (B, C, H, hd) each
        # decay-adjusted operands
        cum_prev = cumc - logwc  # log prod_{s<t}
        r_in = rc * jnp.exp(cum_prev)  # queries see state through decay
        k_dec = kc * jnp.exp(-cumc)  # keys forward-decayed
        # inter-chunk: r_t · S
        inter = jnp.einsum("bchk,bhkv->bchv", r_in, S)
        # intra-chunk: strict lower triangle + bonus diagonal
        att = jnp.einsum("bchk,bdhk->bhcd", r_in, k_dec)
        tri = jnp.tril(jnp.ones((chunk, chunk)), -1)
        att = att * tri[None, None]
        intra = jnp.einsum("bhcd,bdhv->bchv", att, vc)
        bonus = jnp.einsum("bchk,bchk->bch", rc, u[None, None] * kc)[..., None] * vc
        o = inter + intra + bonus
        # state update: S' = diag(prod w) S + sum_s diag(prod_{>s} w) k_s v_s
        total = cumc[:, -1]  # (B, H, hd)
        k_fut = kc * jnp.exp(total[:, None] - cumc)
        S = jnp.exp(total)[..., None] * S + jnp.einsum("bchk,bchv->bhkv", k_fut, vc)
        return S, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, cum, logw))
    Sf, os_ = jax.lax.scan(chunk_step, state0, xs)
    o = jnp.moveaxis(os_, 0, 1).reshape(B, n * chunk, H, hd)
    return o[:, :S], Sf


def rwkv_train(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
    *,
    impl: str = "scan",
    sh=None,
) -> Tuple[jax.Array, Dict]:
    """Time-mix block.  x: (B, S, D) (already normed).  Returns (out, state)."""
    B, S, D = x.shape
    H = cfg.n_rwkv_heads
    hd = D // H
    x_prev = state["x_tm"] if state else jnp.zeros((B, 1, D), x.dtype)
    S0 = state["wkv"] if state else jnp.zeros((B, H, hd, hd), jnp.float32)
    r, k, v, g, w, _ = _rwkv_inputs(p, x, x_prev, cfg)
    rh, kh, vh, wh = (_heads(a, H) for a in (r, k, v, w))
    u = _heads(p["u"].astype(jnp.float32)[None, None], H)[0, 0]
    if impl == "chunked":
        o, S1 = _wkv_chunked(rh, kh, vh, wh, u, S0)
    else:
        o, S1 = _wkv_scan(rh, kh, vh, wh, u, S0)
    o = _group_norm(o.astype(jnp.float32), p["gn_scale"].astype(jnp.float32), H)
    o = (o * g).astype(cfg.cdtype)
    out = jnp.einsum("bsd,de->bse", o, p["wo"].astype(cfg.cdtype))
    new_state = {"x_tm": x[:, -1:], "wkv": S1}
    return out, new_state


def rwkv_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig, sh=None):
    """One-token step; x: (B, 1, D).  O(1) in stream length."""
    out, ns = rwkv_train(p, x, cfg, state=state, impl="scan", sh=sh)
    return out, ns


def rwkv_channel_params(key, cfg: ModelConfig) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": jnp.zeros((2, D), cfg.pdtype),  # shifts for k and r
        "wk": trunc_normal(ks[0], (D, F), 1.0, cfg.pdtype),
        "wv": trunc_normal(ks[1], (F, D), 1.0, cfg.pdtype),
        "wr": trunc_normal(ks[2], (D, D), 1.0, cfg.pdtype),
    }


def rwkv_channel_mix(p: Dict, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig, sh=None):
    cd = cfg.cdtype
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xx = shifted - x
    mu = p["mu"].astype(cd)
    xk, xr = x + xx * mu[0], x + xx * mu[1]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(cd))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(cd)
    if sh is not None:
        k = sh.act_ff(k)
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(cd))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(cd)).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(cd), x[:, -1:]


# ---------------------------------------------------------------------------
# Mamba (selective SSM) -- the SSM half of hymba layers
# ---------------------------------------------------------------------------

CONV_W = 4


def mamba_params(key, cfg: ModelConfig, d_in: Optional[int] = None) -> Dict:
    D = d_in or cfg.d_model
    Di = D  # inner width (hymba runs SSM heads parallel to attn; keep = D)
    N = cfg.ssm_state
    dt_rank = max(1, math.ceil(D / 16))
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype
    return {
        "in_proj": trunc_normal(ks[0], (D, 2 * Di), 1.0, dt),
        "conv_w": trunc_normal(ks[1], (CONV_W, Di), 1.0, dt),
        "x_proj": trunc_normal(ks[2], (Di, dt_rank + 2 * N), 1.0, dt),
        "dt_proj": trunc_normal(ks[3], (dt_rank, Di), 1.0, dt),
        "dt_bias": jnp.asarray(jnp.log(jnp.expm1(jnp.full((Di,), 0.01))), dt),
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (Di, 1))
        ).astype(dt),
        "D": jnp.ones((Di,), dt),
        "out_proj": trunc_normal(ks[4], (Di, D), 1.0, dt),
    }


def mamba_init_state(cfg: ModelConfig, batch: int, layers: int, d_in: Optional[int] = None) -> Dict:
    Di = d_in or cfg.d_model
    N = cfg.ssm_state
    return {
        "h": jnp.zeros((layers, batch, Di, N), jnp.float32),
        "conv": jnp.zeros((layers, batch, CONV_W - 1, Di), jnp.float32),
    }


def _mamba_core(p: Dict, xz: jax.Array, conv_prev: jax.Array, h0: jax.Array, cfg: ModelConfig):
    """xz: (B, S, 2*Di) after in_proj; returns (y (B,S,Di), h_T, conv_tail)."""
    Di = xz.shape[-1] // 2
    N = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    x, z = xz[..., :Di], xz[..., Di:]
    # causal depthwise conv, width CONV_W, with carried left context
    xc = jnp.concatenate([conv_prev.astype(x.dtype), x], axis=1)  # (B, S+3, Di)
    w = p["conv_w"].astype(jnp.float32)
    S = x.shape[1]
    y = sum(
        xc[:, i : i + S].astype(jnp.float32) * w[i][None, None] for i in range(CONV_W)
    )
    x = jax.nn.silu(y)
    proj = jnp.einsum("bsd,de->bse", x.astype(cfg.cdtype), p["x_proj"].astype(cfg.cdtype))
    dt_in, Bc, Cc = jnp.split(proj.astype(jnp.float32), [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32)
    )  # (B, S, Di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (Di, N)
    dA = jnp.exp(dt[..., None] * A[None, None])  # (B, S, Di, N)
    dBx = dt[..., None] * Bc[:, :, None, :] * x[..., None]  # (B, S, Di, N)

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t
        y_t = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y_t

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(Cc, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    ys = jnp.moveaxis(ys, 0, 1) + x * p["D"].astype(jnp.float32)[None, None]
    out = ys * jax.nn.silu(z.astype(jnp.float32))
    return out.astype(cfg.cdtype), hT, xc[:, -(CONV_W - 1) :].astype(jnp.float32)


def mamba_train(
    p: Dict, x: jax.Array, cfg: ModelConfig, state: Optional[Dict] = None, sh=None
) -> Tuple[jax.Array, Dict]:
    B, S, D = x.shape
    Di = p["out_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cfg.cdtype))
    conv_prev = state["conv"] if state else jnp.zeros((B, CONV_W - 1, Di), jnp.float32)
    h0 = state["h"] if state else jnp.zeros((B, Di, cfg.ssm_state), jnp.float32)
    y, hT, conv_tail = _mamba_core(p, xz, conv_prev, h0, cfg)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(cfg.cdtype))
    return out, {"h": hT, "conv": conv_tail}


def mamba_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig, sh=None):
    return mamba_train(p, x, cfg, state=state, sh=sh)
