"""Model configuration schema for the 10-architecture zoo.

One frozen dataclass covers every family (dense / moe / ssm / hybrid /
audio / vlm); family-specific fields are zero/None when unused.  The exact
assigned configs live in :mod:`repro.configs` -- one module per arch id.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "VOCAB_ALIGN"]

# Vocab axes are padded to this multiple so every arch's embedding table can
# be sharded evenly over a 16-wide model axis (51865 and 151655 are not even
# divisible by 2).  Pad logits are masked to -inf in the loss.
VOCAB_ALIGN = 256


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int  # 0 => attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    head_dim: int = 0  # 0 => d_model // n_heads
    window: int = 0  # 0 => full causal; >0 => sliding-window attention
    rope_theta: float = 10_000.0

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm (rwkv / mamba-in-hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0  # rwkv heads; 0 => d_model // 64

    # families / flavour
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    activation: str = "swiglu"  # swiglu | gelu
    pos: str = "rope"  # rope | learned | none
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0  # encoder frontend length (whisper frames / vit patches)
    frontend_tokens: int = 0  # vlm: patch embeddings prepended to the text
    tie_embeddings: bool = False
    max_seq: int = 524_288

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # training-time knobs (overridable per run)
    remat: str = "full"  # none | full | dots
    scan_unroll: bool = False  # unroll the layer scan (dry-run cost pass)
    attn_impl: str = "dense"  # dense | chunked (flash-style online softmax)
    rwkv_impl: str = "scan"  # scan (exact recurrence) | chunked (GLA-style)
    dryrun_n_micro: int = 0  # per-arch microbatch override (0 = size-tiered)
    # store the per-layer scan carry sequence-sharded over the model axis
    # (Megatron-SP-style): the remat stack divides by the TP width; the body
    # all-gathers S per layer (cheap vs the stack's HBM footprint at 405B)
    sp_carry: bool = False
    moe_impl: str = "dense"  # dense (einsum) | dmm (sort/gather) | ep (shard_map all-to-all)

    # ---- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, VOCAB_ALIGN)

    @property
    def n_rwkv_heads(self) -> int:
        return self.ssm_heads or self.d_model // 64

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? SSM state or windowed attn."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter accounting (for MODEL_FLOPS = 6*N*D roofline term) -------
    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_padded, self.n_layers
        hd = self.hd
        n = 0
        # embeddings (+ untied lm head)
        n += V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            H = self.n_rwkv_heads
            per_layer += 4 * D * D  # r, k, v, output
            per_layer += D * D  # gate
            per_layer += 6 * 2 * D * 32  # token-shift loras (x_maa)
            per_layer += 2 * D * 64  # decay lora
            per_layer += 2 * D  # decay base + bonus u
            per_layer += 2 * D + H * 64  # ln scales + group-norm
            per_layer += D * F + F * D + D * D  # channel mix (k, v, r)
        else:
            # attention
            att = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
            if self.family == "hybrid":
                Di, N = D, self.ssm_state
                dt_rank = max(1, math.ceil(D / 16))
                ssm = (
                    D * 2 * Di  # in_proj (x, z)
                    + Di * 4  # conv
                    + Di * (dt_rank + 2 * N)  # x_proj
                    + dt_rank * Di  # dt_proj
                    + Di * N + Di  # A_log, D
                    + Di * D  # out_proj
                )
                per_layer += att + ssm
            else:
                per_layer += att
            # mlp / moe
            if self.is_moe:
                per_layer += D * self.n_experts  # router
                per_layer += self.n_experts * (2 * D * F + F * D)  # swiglu experts
            else:
                mults = 3 if self.activation == "swiglu" else 2
                per_layer += mults * D * F
            # norms
            if self.norm != "nonparametric_ln":
                per_layer += 2 * D
        n += per_layer * L
        if self.enc_dec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = (4 * D * D + 2 * D * F + 2 * D) * self.enc_layers
            dec_cross = (4 * D * D + D) * L
            n += enc + dec_cross
            n += self.enc_seq * D + self.max_seq_emb() * D  # learned pos (enc+dec)
        return n

    def max_seq_emb(self) -> int:
        # whisper's real decoder caps at 448 learned positions; the assigned
        # prefill/decode cells go to 32k, so the table is extended (DESIGN SS6)
        return 32_768 if self.family == "audio" else 0

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        full_experts = self.n_experts * (2 * D * F + F * D) * L
        active_experts = self.top_k * (2 * D * F + F * D) * L
        return self.param_count() - full_experts + active_experts
