"""Mixture-of-Experts with DMM-style dispatch.

The MoE dispatch operator *is* the paper's mapping matrix, live in the model:
a huge block-structured 0/1 operator (tokens x expert-capacity slots) that is
absurd to materialise and cheap as compacted index sets.  Three
implementations, selected by ``cfg.moe_impl``:

  dense  -- scatter/gather dispatch per batch row ("group"): slot positions
            from a cumsum over the expert one-hot, token dropping beyond
            capacity.  The portable baseline; shards over (data: batch,
            model: experts) under jit.
  dmm    -- the paper's Algorithm-6 analogue on a flat token axis: compacted
            index vectors (argsort by expert) + masked gathers, single-shard
            semantics; the optimized data layout for one device/model shard.
  ep     -- shard_map expert parallelism: local routing, all_to_all over the
            ``model`` axis to the expert owners, grouped FFN, all_to_all
            back.  The production path at pod scale.

All three are allclose (up to token-drop tie-breaking, which is made
deterministic by stable sorts) and are property-tested against each other.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import trunc_normal

__all__ = ["moe_params", "moe_apply", "router_aux_loss"]


def moe_params(key, cfg: ModelConfig) -> Dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": trunc_normal(ks[0], (D, E), 1.0, jnp.float32),  # router in f32
        "w_in": trunc_normal(ks[1], (E, D, F), 1.0, cfg.pdtype),
        "w_gate": trunc_normal(ks[2], (E, D, F), 1.0, cfg.pdtype),
        "w_out": trunc_normal(ks[3], (E, F, D), 1.0, cfg.pdtype),
    }


def _route(p: Dict, x: jax.Array, cfg: ModelConfig):
    """x: (..., D) -> (gates (..., k), experts (..., k) int32, probs (..., E))."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32), probs


def router_aux_loss(probs: jax.Array, experts: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balance loss: E * <f_e * p_e>."""
    E = cfg.n_experts
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)  # (..., k, E)
    frac = jnp.mean(jnp.sum(onehot, axis=-2).reshape(-1, E), axis=0) / cfg.top_k
    mean_p = jnp.mean(probs.reshape(-1, E), axis=0)
    return E * jnp.sum(frac * mean_p)


def _expert_ffn(p: Dict, h: jax.Array, cfg: ModelConfig, sh=None) -> jax.Array:
    """h: (E, C, D) -> (E, C, D) through each expert's SwiGLU."""
    cd = cfg.cdtype
    a = jnp.einsum("ecd,edf->ecf", h, p["w_in"].astype(cd))
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(cd))
    a = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * a
    if sh is not None:
        a = sh.act_expert_ff(a)
    return jnp.einsum("ecf,efd->ecd", a, p["w_out"].astype(cd))


# ---------------------------------------------------------------------------
# dense: scatter/gather per batch-row group (jit/GSPMD path)
# ---------------------------------------------------------------------------


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(4, -(-c // 4) * 4)


def _dispatch_indices(experts: jax.Array, E: int, C: int):
    """experts: (T, k) -> (slot (T, k), keep (T, k)) with per-expert cumsum
    positions; tokens beyond an expert's capacity are dropped (keep=0).
    Deterministic: earlier tokens win (paper's 'there cannot be two data
    containers at the same place')."""
    T, k = experts.shape
    flat = experts.reshape(T * k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < C
    return slot.reshape(T, k), keep.reshape(T, k)


def _moe_group(p: Dict, x: jax.Array, cfg: ModelConfig, sh=None) -> jax.Array:
    """One group's MoE: x (T, D) -> (T, D).  vmapped over the batch axis."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    gates, experts, probs = _route(p, x, cfg)
    slot, keep = _dispatch_indices(experts, E, C)
    # scatter tokens into (E, C, D)
    buf = jnp.zeros((E, C, D), cfg.cdtype)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(-1)
    e_flat = experts.reshape(-1)
    s_flat = jnp.where(keep.reshape(-1), slot.reshape(-1), C)  # C = overflow bin
    buf = jnp.pad(buf, ((0, 0), (0, 1), (0, 0)))  # overflow slot
    buf = buf.at[e_flat, s_flat].add(x[tok].astype(cfg.cdtype), mode="drop")
    buf = buf[:, :C]
    out_e = _expert_ffn(p, buf, cfg, sh)  # (E, C, D)
    # gather back, weighted by gates
    got = out_e[e_flat, jnp.minimum(s_flat, C - 1)]  # (T*k, D)
    got = got * (keep.reshape(-1, 1) * gates.reshape(-1, 1)).astype(got.dtype)
    out = jnp.zeros((T, D), cfg.cdtype).at[tok].add(got)
    return out, probs, experts


# ---------------------------------------------------------------------------
# dmm: compacted index-set dispatch (Algorithm-6 analogue, flat token axis)
# ---------------------------------------------------------------------------


def _moe_dmm(p: Dict, x: jax.Array, cfg: ModelConfig, sh=None):
    """Sort-based dispatch: the mapping 'matrix' never exists, only its
    compacted index sets -- token order sorted by expert id, segment
    boundaries from a bincount.  (T, D) -> (T, D)."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    gates, experts, probs = _route(p, x, cfg)
    flat_e = experts.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)  # compacted index set
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(-1)[order]
    e_sorted = flat_e[order]
    # position within expert segment
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_in_e = jnp.arange(T * k) - seg_start[e_sorted]
    keep = pos_in_e < C
    slot = e_sorted * C + jnp.minimum(pos_in_e, C - 1)
    # gather payload through the compacted set (the DMM apply)
    buf = jnp.zeros((E * C, D), cfg.cdtype)
    buf = buf.at[jnp.where(keep, slot, E * C - 1)].add(
        jnp.where(keep[:, None], x[tok].astype(cfg.cdtype), 0)
    )
    out_e = _expert_ffn(p, buf.reshape(E, C, D), cfg, sh).reshape(E * C, D)
    got = out_e[slot] * keep[:, None]
    gate_sorted = gates.reshape(-1)[order]
    out = jnp.zeros((T, D), cfg.cdtype).at[tok].add(got * gate_sorted[:, None].astype(got.dtype))
    return out, probs, experts


# ---------------------------------------------------------------------------
# ep: shard_map all-to-all expert parallelism (production path)
# ---------------------------------------------------------------------------


def _moe_ep_local(p_local: Dict, x: jax.Array, cfg: ModelConfig, axis: str):
    """Runs *inside* shard_map.  x: (T_loc, D) local tokens; p_local holds
    this shard's E_loc experts.  Experts are sharded over ``axis``."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    from ..sharding.specs import lax_axis_size

    n_shards = lax_axis_size(axis)
    E_loc = E // n_shards
    C = _capacity(T, cfg)  # capacity per (expert, source shard)
    # route locally against the full router (router weights replicated)
    gates, experts, probs = _route({"router": p_local["router"]}, x, cfg)
    slot, keep = _dispatch_indices(experts, E, C)
    buf = jnp.zeros((E, C + 1, D), cfg.cdtype)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(-1)
    e_flat = experts.reshape(-1)
    s_flat = jnp.where(keep.reshape(-1), slot.reshape(-1), C)
    buf = buf.at[e_flat, s_flat].add(x[tok].astype(cfg.cdtype), mode="drop")
    buf = buf[:, :C]  # (E, C, D) destined for expert owners
    # all_to_all: split expert axis across shards, concat source shards
    recv = jax.lax.all_to_all(
        buf.reshape(n_shards, E_loc, C, D), axis, split_axis=0, concat_axis=0, tiled=False
    )  # (n_shards, E_loc, C, D): peers' tokens for my experts
    recv = recv.transpose(1, 0, 2, 3).reshape(E_loc, n_shards * C, D)
    ffn_p = {k_: p_local[k_] for k_ in ("w_in", "w_gate", "w_out")}
    out_e = _expert_ffn(ffn_p, recv, cfg)  # (E_loc, n_shards*C, D)
    # send results back
    send = out_e.reshape(E_loc, n_shards, C, D).transpose(1, 0, 2, 3)
    back = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0, tiled=False)
    back = back.reshape(E, C, D)  # my tokens' expert outputs, original layout
    got = back[e_flat, jnp.minimum(s_flat, C - 1)]
    got = got * (keep.reshape(-1, 1) * gates.reshape(-1, 1)).astype(got.dtype)
    out = jnp.zeros((T, D), cfg.cdtype).at[tok].add(got)
    return out, probs, experts


def moe_apply(
    p: Dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    sh=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    impl = cfg.moe_impl
    if impl == "ep" and sh is not None and sh.mesh is not None:
        mesh = sh.mesh
        axis = sh.model_axis
        dp_axes = sh.data_axes  # ('data',) or ('pod', 'data')
        from jax.experimental.shard_map import shard_map

        def local(p_local, xl):
            xl2 = xl.reshape(-1, D)
            out, probs, experts = _moe_ep_local(p_local, xl2, cfg, axis)
            aux = router_aux_loss(probs, experts, cfg)
            return out.reshape(xl.shape), aux

        p_spec = {
            "router": P(),
            "w_in": P(axis, None, None),
            "w_gate": P(axis, None, None),
            "w_out": P(axis, None, None),
        }
        out, aux = shard_map(
            local,
            mesh=mesh,
            in_specs=(p_spec, P(dp_axes, None, None)),
            out_specs=(P(dp_axes, None, None), P()),
            check_rep=False,
        )(p, x)
        return out, jnp.mean(aux)
    if impl == "dmm":
        out, probs, experts = _moe_dmm(p, x.reshape(-1, D), cfg, sh)
        return out.reshape(B, S, D), router_aux_loss(probs, experts, cfg)
    # dense: group per batch row, vmapped
    fn = functools.partial(_moe_group, p, cfg=cfg, sh=sh)
    out, probs, experts = jax.vmap(lambda xb: _moe_group(p, xb, cfg, sh))(x)
    return out, router_aux_loss(probs, experts, cfg)
