"""The whole-program model: import resolution, call graph, reachability,
buffer-donation dataflow.

PR 7's rules were per-file AST checks, which means every cross-module
contract -- the single-writer coordinator, epoch-pinned in-flight chunks,
``donate_argnums`` buffer donation that is a **no-op on the CPU CI
backend** -- was enforced only where a hard-coded function name happened to
match.  :class:`Project` gives rules the three whole-program facts those
contracts need:

  * **symbol resolution** through the module graph -- every analyzed file
    becomes a :class:`Module` with an import table, so ``from ..kernels.ops
    import dmm_apply_columnar as X; X(...)`` resolves to the same function
    as the direct call;
  * an **approximate call graph** -- call edges resolve by import-aware
    qualified name first, then fall back to bare-name matching for
    attribute calls (``self.engine.dispatch(...)`` links to every known
    ``dispatch``).  Deliberately an over-approximation: reachability-scoped
    rules would rather scan one extra function than miss the hot path
    through a wrapper;
  * **reachability sets** -- :meth:`Project.reachable` (transitive callees
    of a seed set) and the derived :meth:`Project.hot_path` (everything
    reachable from engine ``densify``/``dispatch``/``consume``), replacing
    the hard-coded name scoping in ``hot_loop.py`` / ``host_sync.py``; and
    :meth:`Project.only_called_from`, the caller-side dual used to resolve
    wrappers of ``StateCoordinator.apply``;
  * the **donation map** -- functions returning ``jax.jit(...,
    donate_argnums=...)`` programs are donation *factories*; wrappers that
    pass a parameter into a factory program's donated position donate that
    parameter in turn (``ops.dmm_apply_columnar`` donates ``packed``).
    :mod:`repro.analysis.rules.donated_buffer` flags reads after the
    donated call.

``Project`` is itself a ``Sequence[FileCtx]``, so every pre-existing
``check_project(ctxs)`` implementation (kernel-ref-parity) keeps working
unchanged; rules that need the model call :func:`as_project` (a no-op for
the instance :func:`repro.analysis.core.analyze` builds ONCE per run).
"""

from __future__ import annotations

import ast
import re
from typing import (
    Dict,
    Iterable,
    Iterator,
    List,
    Optional,
    Sequence,
    Set,
    Tuple,
)

from .core import FileCtx

__all__ = [
    "attr_chain",
    "as_project",
    "module_name",
    "FunctionInfo",
    "Module",
    "Project",
]


def attr_chain(node: ast.expr) -> Optional[str]:
    """The dotted source chain of a Name/Attribute tree (``a.b.c``), or None
    when any link is a call/subscript/literal -- the currency of the
    dataflow rules (chains compare textually)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def module_name(ctx: FileCtx) -> str:
    """Dotted module name for one analyzed file.

    Everything after the LAST ``src`` path component when present (so a
    tmp-dir fixture tree ``/tmp/x/src/repro/etl/e.py`` names ``repro.etl.e``
    exactly like the real one), otherwise every path component -- enough for
    repo-relative ``benchmarks/run.py`` -> ``benchmarks.run``.
    """
    parts = [p for p in ctx.path.parts if p not in ("/", "\\")]
    if "src" in parts:
        parts = parts[len(parts) - parts[::-1].index("src") :]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][: -len(".py")]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class FunctionInfo:
    """One function or method definition in the analyzed set."""

    def __init__(
        self,
        qname: str,
        module: "Module",
        node: ast.FunctionDef,
        cls: Optional[str],
    ) -> None:
        self.qname = qname
        self.name = node.name
        self.cls = cls
        self.module = module
        self.node = node
        # donated positional-arg positions -> parameter name (filled by the
        # donation fixpoint; empty for non-donating functions)
        self.donates: Dict[int, str] = {}

    @property
    def ctx(self) -> FileCtx:
        return self.module.ctx

    def params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    def __repr__(self) -> str:
        return f"FunctionInfo({self.qname})"


class Module:
    """One analyzed file as a module: import table + owned definitions."""

    def __init__(self, ctx: FileCtx) -> None:
        self.ctx = ctx
        self.name = module_name(ctx)
        self.is_package = ctx.path.name == "__init__.py"
        self.imports: Dict[str, str] = {}  # local name -> imported qname
        self.top_level: Set[str] = set()  # top-level def/class names
        self._parse_imports()

    def _parse_imports(self) -> None:
        for node in ast.walk(self.ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.asname:
                        self.imports[alias.asname] = alias.name
                    else:
                        # `import a.b.c` binds only the root name `a`
                        root = alias.name.split(".")[0]
                        self.imports[root] = root
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:
                    # relative import: level 1 is the containing package --
                    # which for an __init__.py is the module name itself
                    up = node.level - 1 if self.is_package else node.level
                    parts = self.name.split(".")
                    anchor = parts[: len(parts) - up] if up else parts
                    base = ".".join(anchor + ([node.module] if node.module else []))
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    self.imports[local] = f"{base}.{alias.name}" if base else alias.name

    def resolve(self, chain: str) -> Optional[str]:
        """Resolve a dotted source chain to a qualified name through this
        module's imports and top-level definitions, or None."""
        head, _, rest = chain.partition(".")
        if head in self.imports:
            base = self.imports[head]
            return f"{base}.{rest}" if rest else base
        if head in self.top_level:
            return f"{self.name}.{chain}" if self.name else chain
        return None


class _DefCollector(ast.NodeVisitor):
    """Collect every function/method of a module with its class context."""

    def __init__(self, module: Module, out: Dict[str, FunctionInfo]) -> None:
        self.module = module
        self.out = out
        self._cls: Optional[str] = None

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.module.top_level.add(node.name)
        prev, self._cls = self._cls, node.name
        for child in node.body:
            self.visit(child)
        self._cls = prev

    def _visit_def(self, node: ast.FunctionDef) -> None:
        # function bodies are not descended into: nested defs (kernel
        # closures) are not separate functions in the model -- ast.walk over
        # the owner's node attributes their statements and call edges to the
        # enclosing function
        if self._cls is None:
            self.module.top_level.add(node.name)
        parts = [self.module.name] if self.module.name else []
        if self._cls:
            parts.append(self._cls)
        parts.append(node.name)
        qname = ".".join(parts)
        self.out[qname] = FunctionInfo(qname, self.module, node, self._cls)

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def  # type: ignore[assignment]


def _jit_donated_positions(call: ast.Call, module: Module) -> Tuple[int, ...]:
    """Donated arg positions of a ``jax.jit(..., donate_argnums=...)`` call
    (empty when the call is not a donating jit).  Conditional donation
    (``(0,) if donate else ()`` -- the CPU-CI-invisible case) counts as
    donating: that is the whole point of the rule."""
    fn = call.func
    chain = attr_chain(fn)
    is_jit = False
    if chain is not None:
        resolved = module.resolve(chain) or chain
        is_jit = resolved in ("jax.jit", "jit") or resolved.endswith(".jit")
    if not is_jit:
        return ()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return tuple(
                sorted(
                    {
                        n.value
                        for n in ast.walk(kw.value)
                        if isinstance(n, ast.Constant) and isinstance(n.value, int)
                        and not isinstance(n.value, bool)
                    }
                )
            )
    return ()


class Project(Sequence[FileCtx]):
    """The whole-program model over one analyzer run's file set.

    Sequence protocol: iterating/indexing a Project yields its
    :class:`FileCtx` objects, so legacy ``check_project(ctxs)``
    implementations run unmodified.
    """

    def __init__(self, ctxs: Sequence[FileCtx]) -> None:
        self._ctxs = list(ctxs)
        self.modules: Dict[str, Module] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self.calls: Dict[str, Set[str]] = {}
        self.callers: Dict[str, Set[str]] = {}
        self._hot: Optional[Set[str]] = None
        for ctx in self._ctxs:
            mod = Module(ctx)
            self.modules[mod.name] = mod
            ctx.module = mod
            _DefCollector(mod, self.functions).visit(ctx.tree)
        for info in self.functions.values():
            self.by_name.setdefault(info.name, []).append(info)
        self._build_call_graph()
        self._build_donation_map()

    # -- Sequence[FileCtx] ----------------------------------------------------
    def __len__(self) -> int:
        return len(self._ctxs)

    def __getitem__(self, i: int) -> FileCtx:  # type: ignore[override]
        return self._ctxs[i]

    def __iter__(self) -> Iterator[FileCtx]:
        return iter(self._ctxs)

    # -- call graph -----------------------------------------------------------
    def _callees_of(self, info: FunctionInfo) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            out.update(t.qname for t in self.resolve_call(info.module, node.func))
        return out

    def resolve_call(
        self, module: Module, func: ast.expr
    ) -> List[FunctionInfo]:
        """Candidate targets of one call expression.

        A chain that resolves through the module's imports/definitions to a
        known function (or a known class -- then its ``__init__``) is an
        exact edge; an unresolved attribute call falls back to every known
        function with the same bare name (over-approximate by design).
        """
        chain = attr_chain(func)
        if chain is None:
            return []
        qname = module.resolve(chain)
        if qname is not None:
            if qname in self.functions:
                return [self.functions[qname]]
            if f"{qname}.__init__" in self.functions:
                return [self.functions[f"{qname}.__init__"]]
            # imported-but-unanalyzed symbol: name-match on the RESOLVED tail
            # (`from ops import dmm_apply_columnar as X` still finds every
            # known dmm_apply_columnar even when `ops` isn't in the file set)
            return list(self.by_name.get(qname.rsplit(".", 1)[-1], []))
        if "." in chain:
            # unresolved attribute call (self.engine.dispatch): every known
            # function with the same bare name
            return list(self.by_name.get(chain.rsplit(".", 1)[-1], []))
        # a bare name that resolved nowhere is a local variable or builtin
        return []

    def _build_call_graph(self) -> None:
        for qname, info in self.functions.items():
            callees = self._callees_of(info)
            callees.discard(qname)
            self.calls[qname] = callees
            for c in callees:
                self.callers.setdefault(c, set()).add(qname)

    # -- reachability ---------------------------------------------------------
    def reachable(self, seeds: Iterable[str]) -> Set[str]:
        """Seeds plus every transitive callee (qualified names)."""
        seen: Set[str] = set()
        stack = [s for s in seeds if s in self.functions]
        while stack:
            q = stack.pop()
            if q in seen:
                continue
            seen.add(q)
            stack.extend(self.calls.get(q, ()))
        return seen

    def seeds_matching(
        self, pattern: "re.Pattern[str]", *, packages: Sequence[Tuple[str, ...]] = ()
    ) -> Set[str]:
        """qnames of functions whose bare NAME matches ``pattern``, optionally
        restricted to files inside any of ``packages`` (path-part tuples)."""
        out: Set[str] = set()
        for info in self.functions.values():
            if not pattern.search(info.name):
                continue
            if packages and not any(info.ctx.in_package(*p) for p in packages):
                continue
            out.add(info.qname)
        return out

    _HOT_SEED = re.compile(
        r"densify|dispatch|_chunk_layout|_pack_columnar|^(consume|consume_groups)$"
    )

    def hot_path(self) -> Set[str]:
        """The per-chunk path: transitive callees of the engine
        ``densify``/``dispatch``/``consume`` entry points (plus the hot
        routing helpers), seeded in ``repro.etl``/``repro.kernels`` -- or
        anywhere when neither package is in the file set, so bare fixture
        trees exercise the same scoping."""
        if self._hot is None:
            pkgs: Sequence[Tuple[str, ...]] = (("repro", "etl"), ("repro", "kernels"))
            seeds = self.seeds_matching(self._HOT_SEED, packages=pkgs)
            if not seeds:
                seeds = self.seeds_matching(self._HOT_SEED)
            self._hot = self.reachable(seeds)
        return self._hot

    def only_called_from(self, qname: str, root: str) -> bool:
        """True when every caller path of ``qname`` terminates at ``root``
        (the wrapper-resolution dual of :meth:`reachable`): ``qname`` is a
        private helper of ``root`` and inherits its privileges.  A function
        with any caller chain escaping to another root -- or with no callers
        at all -- is not."""
        if qname == root:
            return True
        seen: Set[str] = set()
        stack = [qname]
        while stack:
            q = stack.pop()
            if q in seen or q == root:
                continue
            seen.add(q)
            callers = self.callers.get(q, set())
            if not callers:
                return False  # an open entry point, not a private helper
            stack.extend(callers)
        return True

    # -- buffer donation ------------------------------------------------------
    def _build_donation_map(self) -> None:
        """Two passes: (1) donation factories -- functions RETURNING a
        ``jax.jit(..., donate_argnums=...)`` program; (2) a fixpoint
        propagating donation through wrappers that feed a parameter into a
        donated position of a factory program or another donating function.
        """
        self.factories: Dict[str, Tuple[int, ...]] = {}
        for qname, info in self.functions.items():
            for node in ast.walk(info.node):
                if isinstance(node, ast.Return) and isinstance(node.value, ast.Call):
                    pos = _jit_donated_positions(node.value, info.module)
                    if pos:
                        self.factories[qname] = pos
        # module-level programs: ``f = jax.jit(..., donate_argnums=...)`` or
        # ``g = factory(...)`` bound at import time -- calling the bound name
        # (locally or through an import) donates
        self.programs: Dict[str, Tuple[int, ...]] = {}
        for mod in self.modules.values():
            for stmt in mod.ctx.tree.body:
                if not (
                    isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call)
                ):
                    continue
                pos = self.donated_positions(mod, stmt.value)
                if not pos:
                    continue
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        q = f"{mod.name}.{tgt.id}" if mod.name else tgt.id
                        self.programs[q] = pos
                        mod.top_level.add(tgt.id)
        changed = True
        while changed:
            changed = False
            for qname, info in self.functions.items():
                params = info.params()
                for call, donated in self._donating_calls(info):
                    for p in donated:
                        if p >= len(call.args):
                            continue
                        arg = call.args[p]
                        if isinstance(arg, ast.Name) and arg.id in params:
                            i = params.index(arg.id)
                            if i not in info.donates:
                                info.donates[i] = arg.id
                                changed = True

    def _donating_calls(
        self, info: FunctionInfo
    ) -> List[Tuple[ast.Call, Tuple[int, ...]]]:
        """Call sites inside ``info`` whose positional args include donated
        positions: direct calls of donating functions, and calls OF a
        factory's return value (``_columnar_program(...)(packed, ...)``)."""
        out: List[Tuple[ast.Call, Tuple[int, ...]]] = []
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            pos = self.donated_positions(info.module, node.func)
            if pos:
                out.append((node, pos))
        return out

    def donated_positions(
        self, module: Module, func: ast.expr
    ) -> Tuple[int, ...]:
        """Donated positional-arg positions of calling ``func``, resolved
        through factories, wrappers and imports (empty when not donating)."""
        # factory-result-called-immediately: factory(...)(args)
        if isinstance(func, ast.Call):
            for t in self.resolve_call(module, func.func):
                if t.qname in self.factories:
                    return self.factories[t.qname]
            # direct jax.jit(fn, donate_argnums=...)(args)
            return _jit_donated_positions(func, module)
        for t in self.resolve_call(module, func):
            if t.donates:
                return tuple(sorted(t.donates))
            if t.qname in self.factories:
                # calling the factory itself donates nothing; its RESULT does
                continue
        chain = attr_chain(func)
        if chain is not None:
            q = module.resolve(chain)
            if q is not None and q in self.programs:
                return self.programs[q]
        return ()

    def donating_function(
        self, module: Module, func: ast.expr
    ) -> Optional[FunctionInfo]:
        """The resolved donating callee of a call expression, if any."""
        for t in self.resolve_call(module, func):
            if t.donates:
                return t
        return None


def as_project(ctxs: Sequence[FileCtx]) -> Project:
    """The Project for a ``check_project`` argument: identity for the one
    :func:`repro.analysis.core.analyze` built, a fresh build for a plain
    FileCtx list (direct rule unit tests)."""
    return ctxs if isinstance(ctxs, Project) else Project(list(ctxs))
