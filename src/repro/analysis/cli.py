"""Command-line front end: ``python -m repro.analysis <paths>``."""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence, TextIO

from .core import RULES, Report, analyze


def _split(v: Optional[str]) -> Optional[List[str]]:
    if not v:
        return None
    return [s.strip() for s in v.split(",") if s.strip()]


def _render_github(report: Report, out: TextIO) -> None:
    """GitHub Actions workflow-command annotations: each finding renders as
    an ``::error`` line that CI overlays on the diff at file:line.  The
    trailing plain-text summary line is NOT a workflow command, so it shows
    in the raw log without extra annotations.  Exit codes are unchanged
    (0 clean / 1 findings / 2 usage error)."""
    for f in report.findings:
        # messages must be single-line for the workflow-command grammar
        msg = f.message.replace("\n", " ")
        print(
            f"::error file={f.path},line={f.line},col={f.col},"
            f"title=repro.analysis[{f.rule}]::{msg}",
            file=out,
        )
    status = "OK" if report.ok else f"{len(report.findings)} finding(s)"
    print(
        f"repro.analysis: {status} "
        f"({report.n_files} files, {len(report.rules)} rules, "
        f"{len(report.waived)} waived)",
        file=out,
    )


def _render_text(report: Report, out: TextIO) -> None:
    for f in report.findings:
        print(f.render(), file=out)
    if report.waived:
        print(f"-- {len(report.waived)} waived:", file=out)
        for f, w in report.waived:
            print(f"   {f.render()}  (waived: {w.reason})", file=out)
    status = "OK" if report.ok else f"{len(report.findings)} finding(s)"
    print(
        f"repro.analysis: {status} "
        f"({report.n_files} files, {len(report.rules)} rules)",
        file=out,
    )


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant analyzer for the METL repo "
        "(rule catalog: python -m repro.analysis --list-rules).",
    )
    parser.add_argument("paths", nargs="*", help="files or directories to analyze")
    parser.add_argument(
        "--select", metavar="IDS", help="comma-separated rule ids to run (only)"
    )
    parser.add_argument(
        "--ignore", metavar="IDS", help="comma-separated rule ids to skip"
    )
    parser.add_argument(
        "--output", choices=("text", "json", "github"), default="text",
        help="stdout format (default: text); 'github' emits "
        "::error workflow-command annotations for CI logs",
    )
    parser.add_argument(
        "--report", metavar="FILE",
        help="also write the JSON report to FILE (any --output)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        from . import rules as _rules  # noqa: F401

        for rid, rule in sorted(RULES.items()):
            print(f"{rid}\n    {rule.title}\n    why: {rule.motivation}")
        return 0

    if not args.paths:
        parser.print_usage(sys.stderr)
        print("error: no paths given", file=sys.stderr)
        return 2

    try:
        report = analyze(
            args.paths, select=_split(args.select), ignore=_split(args.ignore)
        )
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.report:
        with open(args.report, "w") as fh:
            json.dump(report.as_dict(), fh, indent=2)
            fh.write("\n")

    if args.output == "json":
        json.dump(report.as_dict(), sys.stdout, indent=2)
        print()
    elif args.output == "github":
        _render_github(report, sys.stdout)
    else:
        _render_text(report, sys.stdout)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
