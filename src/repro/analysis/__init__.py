"""repro.analysis -- AST-based invariant analyzer for the METL repo.

Replaces ci.sh's two ``git grep`` encapsulation gates with a real static
analyzer: each rule encodes an invariant a past PR fought for, so that the
regression class it names fails CI instead of review.  Run it as::

    python -m repro.analysis src benchmarks examples
    python -m repro.analysis --list-rules
    python -m repro.analysis src --select private-reach-in --output json

Exit codes: 0 clean, 1 findings, 2 usage/internal error.

The rule catalog (see docs/analysis.md for the long-form version):

``private-reach-in``
    No private METLApp/engine/Registry attribute access outside the owning
    package (``repro.etl`` / ``repro.core``).  AST successor of the two
    grep gates: tracks aliases (``shadow = app; shadow._fused``), ignores
    strings/comments, and keeps the known private names as an any-receiver
    backstop.  Motivation: PR 3/PR 5 moved launchers and benchmarks onto
    the public engine protocol.

``host-sync-in-hot-path``
    ``dispatch``/``_run_async``/``dmm_apply*`` must never read back or
    block on device values; ``emit`` is the one sync point and its
    readbacks must carry a waiver comment.  Motivation: PR 3's async
    double buffer and PR 6's one-transfer-per-chunk contract die silently
    when a stray ``np.asarray`` lands in the dispatch path.

``hot-path-python-loop``
    No per-event python loops or ``.payload()`` dict walks inside
    densify/dispatch functions; per-column/per-shard loops are fine.
    Motivation: the PR-1 and PR-4 regression class (8.5x densify
    throughput once vectorised).

``control-plane-purity``
    ``ControlEvent.mutate()`` is callable only from
    ``StateCoordinator.apply`` (the single writer that logs events for
    replay), and every ControlEvent subclass must be a frozen dataclass.
    Motivation: PR 5's bit-exact control_log replay.

``jit-cache-hygiene``
    ``lru_cache``-wrapped jit program builders (kernels/ops.py) must take
    only annotated, hashable static parameters; no ``*args``, no array
    annotations, no unhashable literals at call sites.  Motivation: a
    churning cache key recompiles every chunk without failing anything.

``kernel-ref-parity``
    Every Pallas kernel in ``kernels/`` has a pure-jnp twin in
    ``kernels/ref.py`` and a test that references both the kernel and its
    twin.  Motivation: the onehot test compared against the wrong twin.

The cross-module rules ride the whole-program model in
:mod:`repro.analysis.project` (import-aware symbol resolution, an
approximate call graph, hot-path reachability, and a ``donate_argnums``
dataflow map, built ONCE per run):

``donated-buffer-reuse``
    No read of a buffer after it was passed in a donated position of a
    jitted program.  Motivation: ``donate_argnums`` is a no-op on the CPU
    CI backend -- a reuse passes every test and corrupts on TPU/GPU
    (PR 6's device-densify contract).

``single-writer-control``
    Only ``StateCoordinator.apply`` (resolved through wrappers via the
    call graph) may append to ``control_log`` or mutate coordinator
    state.  Motivation: PR 5's bit-exact control-log replay has exactly
    one writer.

``epoch-pin-escape``
    Every ``DenseChunk``/``ColumnarDense`` construction carries its
    ``plan=`` epoch pin, and no ``.plan`` read through a chunk crosses a
    coordinator mutation in the same scope.  Motivation: PR 5's
    epoch-transition contract -- an unpinned in-flight chunk maps rows
    with the wrong epoch's plan.

``transfer-accounting``
    No host->device conversion reachable from the per-chunk dispatch
    path outside the single waived ``_to_device`` site in ``engines.py``.
    Motivation: PR 6's one-transfer-per-chunk contract, enforced by
    reachability instead of by whichever configurations the bench runs.

``plan-publish-single-site``
    Only ``repro.etl.plan`` (the PlanManager) and ``repro.core.dmm_jax``
    (the lowering layer) may call the fused-plan builders
    (``compile_fused`` / ``compile_fused_sharded`` / ``recompile_columns``
    / ``splice_fused``), construct ``FusedDMM``/``ShardedFusedDMM``, or
    cut a ``PlanPublished`` event; ``compile_dpm`` stays free.
    Motivation: PR 9's epoch counter, tiering residency, rebuild
    accounting and PlanPublished replay all hang off one build path -- a
    hand-built plan is an unmanaged epoch that dodges every contract.

Waivers: append ``# metl: allow[rule-id] reason`` to the offending line
(or the line above as a standalone comment; on a ``def`` line it covers
the whole function).  The reason is mandatory -- a reasonless waiver or an
unknown rule id is itself a finding (``bad-waiver``), a well-formed
waiver that suppresses nothing is ``unused-waiver``, and neither audit
finding can be waived.
"""

from .core import (  # noqa: F401
    Finding,
    FileCtx,
    Report,
    Rule,
    RULES,
    Waiver,
    analyze,
    collect_files,
    register,
)
from .project import Project, as_project  # noqa: F401

__all__ = [
    "Finding",
    "FileCtx",
    "Project",
    "Report",
    "Rule",
    "RULES",
    "Waiver",
    "analyze",
    "as_project",
    "collect_files",
    "register",
]
