"""transfer-accounting: the per-chunk path crosses host->device in one
place.

PR 6's device-densify contract is ONE packed host->device transfer per
chunk (the int32 columnar buffer into the fused dispatch); the legacy
host-densify branch makes its four array transfers at one accounted spot
(``stats["transfers"] += ...`` next to the conversions).  The roofline and
the bench gate both *price* chunks by that accounting, so a stray
``jnp.asarray``/``jax.device_put`` on the per-chunk path is double
trouble: it adds an unacounted transfer (the roofline model silently
diverges from reality) and on a real accelerator it puts PCIe traffic
back on the path PR 6 took it off.

Scope (project model): functions inside :meth:`Project.hot_path` --
transitive callees of the engine ``densify``/``dispatch``/``consume``
entry points -- restricted to ``repro.etl`` files.  Kernel-internal
``jnp.asarray(fill, dtype)`` casts run inside traced code (no transfer)
and are out of scope.  The flagged conversions: ``jnp.asarray`` /
``jnp.array`` / ``jnp.ascontiguousarray`` and ``jax.device_put``,
resolved through import aliases.  The engines' single conversion site
(``_to_device``) carries the rule's one waiver; new conversions belong
there, next to the accounting.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Sequence

from ..core import FileCtx, Finding, Rule, register
from ..project import Project, as_project, attr_chain

_JNP_CONVERT = frozenset({"asarray", "array", "ascontiguousarray"})


def _conversion(chain: Optional[str], resolved: Optional[str]) -> Optional[str]:
    """The pretty name of a host->device conversion call, or None."""
    for c in (resolved, chain):
        if not c:
            continue
        parts = c.split(".")
        if parts[-1] == "device_put" and parts[0] == "jax":
            return "jax.device_put"
        if parts[-1] in _JNP_CONVERT and parts[0] in ("jnp", "jax"):
            # jnp.asarray / jax.numpy.asarray
            if parts[0] == "jnp" or (len(parts) > 2 and parts[1] == "numpy"):
                return f"jnp.{parts[-1]}"
    return None


@register
class TransferAccounting(Rule):
    id = "transfer-accounting"
    title = "no host->device conversion on the per-chunk path outside the accounted site"
    motivation = (
        "PR 6's one-packed-transfer-per-chunk contract and the roofline's "
        "transfer pricing both assume every host->device crossing happens "
        "at the accounted site; a stray jnp.asarray on the hot path puts "
        "unacounted PCIe traffic back where PR 6 removed it"
    )

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        project = as_project(ctxs)
        hot = project.hot_path()
        for qname in sorted(hot):
            info = project.functions[qname]
            if not info.ctx.in_package("repro", "etl"):
                continue
            ctx = info.ctx
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                resolved = (
                    info.module.resolve(chain)
                    if chain is not None and info.module is not None
                    else None
                )
                conv = _conversion(chain, resolved)
                if conv is None:
                    continue
                yield ctx.finding(
                    self.id,
                    node,
                    f"{conv}(...) in hot-path function {info.name}() is a "
                    "host->device transfer the per-chunk accounting never "
                    "sees; route it through the engines' accounted "
                    "conversion site (_to_device) or move it out of the "
                    "per-chunk path",
                )
