"""bad-waiver / unused-waiver: the waiver machinery audits itself.

Both rules are enforced inside :func:`repro.analysis.core.analyze` rather
than in ``check_file`` -- ``bad-waiver`` fires while waivers are parsed
(before any rule runs), and ``unused-waiver`` can only be judged AFTER
every selected rule has run and the raw findings are matched against the
waiver spans.  The classes here exist so the two ids are first-class
rules: selectable (``--select bad-waiver,unused-waiver``), listed by
``--list-rules``, and counted in the report's rule set.

``unused-waiver`` is the ``warn_unused_ignores`` shape: a ``# metl:
allow[rule-id] reason`` comment that suppresses nothing is itself a
finding, so waivers cannot rot in place after the code they excused is
refactored away.  A waiver is "used" when ANY raw finding falls inside
its span -- even one claimed by an earlier overlapping waiver -- and is
only judged when every rule it names actually ran in this invocation
(under ``--select``, a waiver for an unselected rule is skipped, not
flagged).  Neither rule can itself be waived: the machinery can't excuse
its own misuse.
"""

from __future__ import annotations

from ..core import Rule, register


@register
class BadWaiver(Rule):
    id = "bad-waiver"
    title = "every waiver carries a reason and names known rule ids"
    motivation = (
        "the reason text is the reviewable artifact -- a bare allow[] is "
        "indistinguishable from a silenced accident; enforced during waiver "
        "parsing in core.analyze, unwaivable"
    )


@register
class UnusedWaiver(Rule):
    id = "unused-waiver"
    title = "a waiver that suppresses nothing is a stale waiver"
    motivation = (
        "waivers rot: the excused code gets refactored away and the comment "
        "keeps silently licensing the next accident on that line; judged "
        "after waiver matching in core.analyze (mypy's warn_unused_ignores "
        "shape), unwaivable"
    )
