"""jit-cache-hygiene: lru_cache-wrapped jit program builders take only
hashable, annotated static arguments.

``kernels/ops.py`` builds its sharded/columnar programs inside
``@functools.lru_cache`` factories (``_sharded_program``,
``_columnar_program``, ``_columnar_sharded_program``) so the ``jax.jit``
object -- and therefore its compilation cache -- is reused across chunks.
The failure mode this rule exists for is *silent*: pass an unhashable
value and lru_cache raises immediately (loud, fine), but pass a value
that hashes differently every call (a fresh Mesh per chunk, a float read
from an array, a tuple rebuilt from a list) and every chunk gets a fresh
jit program -- correctness is untouched while compile time is added to
every chunk.  The throughput bench reads as "jax got slower", not "the
cache key churned".

Checks, for any lru_cache-decorated function whose body builds a jit
program (calls ``jax.jit`` / ``pjit`` / ``shard_map``):

  * ``*args``/``**kwargs`` are flagged (unauditable cache key);
  * every parameter must be annotated -- the annotation is how the next
    reader (and this rule) audits the cache key;
  * annotations must name hashable-by-value types (str/int/float/bool/
    bytes/tuple/frozenset/Mesh/Hashable/...); array annotations
    (``jax.Array``/``jnp.ndarray``/``np.ndarray``) are flagged outright:
    arrays are unhashable, and "it worked" means someone passed a scalar
    that will churn the key later.

Same-file call sites of a cached builder passing list/dict/set literals
are flagged too (unhashable at runtime).
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from ..core import FileCtx, Finding, Rule, register

_HASHABLE = frozenset(
    {
        "str",
        "int",
        "float",
        "bool",
        "bytes",
        "complex",
        "tuple",
        "Tuple",
        "frozenset",
        "FrozenSet",
        "Mesh",
        "AbstractMesh",
        "Hashable",
        "Optional",
        "Union",
        "Literal",
        "Callable",
        "None",
        "NoneType",
        "type",
        "Type",
        "Enum",
        "DTypeLike",
        "dtype",
    }
)

_ARRAYISH = frozenset({"Array", "ndarray", "ArrayLike", "DeviceArray"})

_JIT_NAMES = frozenset({"jit", "pjit", "shard_map"})


def _is_lru_cache(dec: ast.expr) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    if isinstance(target, ast.Attribute):
        return target.attr in ("lru_cache", "cache")
    if isinstance(target, ast.Name):
        return target.id in ("lru_cache", "cache")
    return False


def _builds_jit(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            name = (
                f.attr
                if isinstance(f, ast.Attribute)
                else f.id if isinstance(f, ast.Name) else None
            )
            if name in _JIT_NAMES:
                return True
    return False


def _root_names(annot: ast.expr) -> List[str]:
    """The identifier(s) that decide hashability of an annotation."""
    if isinstance(annot, ast.Name):
        return [annot.id]
    if isinstance(annot, ast.Attribute):
        return [annot.attr]
    if isinstance(annot, ast.Constant):
        if annot.value is None:
            return ["None"]
        if isinstance(annot.value, str):
            return [annot.value.strip().rsplit(".", 1)[-1].split("[", 1)[0]]
        return []
    if isinstance(annot, ast.Subscript):
        # Optional[X] / Union[X, Y] delegate to the args; Tuple[...] etc.
        # are hashable by the root name alone
        roots = _root_names(annot.value)
        if roots and roots[0] in ("Optional", "Union"):
            sl = annot.slice
            elts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
            out: List[str] = []
            for el in elts:
                out.extend(_root_names(el))
            return out
        return roots
    if isinstance(annot, ast.BinOp) and isinstance(annot.op, ast.BitOr):
        return _root_names(annot.left) + _root_names(annot.right)
    return []


@register
class JitCacheHygiene(Rule):
    id = "jit-cache-hygiene"
    title = "lru_cache'd jit builders take only annotated hashable static args"
    motivation = (
        "a churning cache key on ops.py's program builders recompiles every "
        "chunk -- results stay correct, the bench just quietly reports jax "
        "as slow (the PR-6 near-miss with per-chunk Mesh objects)"
    )

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        cached: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(_is_lru_cache(d) for d in node.decorator_list):
                continue
            if not _builds_jit(node):
                continue
            cached.add(node.name)
            yield from self._check_builder(ctx, node)
        if cached:
            yield from self._check_call_sites(ctx, cached)

    def _check_builder(self, ctx: FileCtx, fn: ast.FunctionDef) -> Iterator[Finding]:
        args = fn.args
        if args.vararg is not None or args.kwarg is not None:
            star = args.vararg or args.kwarg
            yield ctx.finding(
                self.id,
                fn,
                f"cached jit builder {fn.name}() takes *{star.arg}: the "
                "cache key cannot be audited; spell every static arg out",
            )
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            yield from self._check_param(ctx, fn, a)

    def _check_param(
        self, ctx: FileCtx, fn: ast.FunctionDef, a: ast.arg
    ) -> Iterator[Finding]:
        if a.annotation is None:
            yield ctx.finding(
                self.id,
                a,
                f"parameter '{a.arg}' of cached jit builder {fn.name}() is "
                "unannotated; annotate it with a hashable type so the "
                "cache key is auditable",
            )
            return
        roots = _root_names(a.annotation)
        bad = self._bad_root(roots)
        if bad is not None:
            hint = (
                "arrays are unhashable and churn the key"
                if bad in _ARRAYISH
                else "hash identity is not hash-by-value"
            )
            yield ctx.finding(
                self.id,
                a,
                f"parameter '{a.arg}: {ctx.segment(a.annotation)}' of cached "
                f"jit builder {fn.name}() is not a hashable static type "
                f"({hint}); pass a str/int/tuple key instead",
            )

    @staticmethod
    def _bad_root(roots: List[str]) -> Optional[str]:
        for r in roots:
            if r in _ARRAYISH:
                return r
            if r not in _HASHABLE:
                return r
        return None if roots else "?"

    def _check_call_sites(self, ctx: FileCtx, cached: Set[str]) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            name = (
                f.id
                if isinstance(f, ast.Name)
                else f.attr if isinstance(f, ast.Attribute) else None
            )
            if name not in cached:
                continue
            values = list(node.args) + [kw.value for kw in node.keywords]
            for v in values:
                if isinstance(v, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                  ast.DictComp, ast.SetComp)):
                    yield ctx.finding(
                        self.id,
                        v,
                        f"unhashable literal passed to cached jit builder "
                        f"{name}(); use a tuple/frozenset",
                    )
