"""Built-in rule modules; importing this package registers all of them."""

from . import (  # noqa: F401
    control_purity,
    host_sync,
    hot_loop,
    jit_cache,
    kernel_parity,
    private_reach_in,
)
