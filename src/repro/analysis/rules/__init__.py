"""Built-in rule modules; importing this package registers all of them."""

from . import (  # noqa: F401
    control_purity,
    donated_buffer,
    epoch_pin,
    host_sync,
    hot_loop,
    jit_cache,
    kernel_parity,
    plan_publish,
    private_reach_in,
    single_writer,
    transfer_accounting,
    waivers,
)
