"""hot-path-python-loop: densification must stay vectorised -- no per-event
python loops or payload-dict walks in densify/dispatch functions.

PR 1 replaced the per-block, per-event python mapping walk with one fused
dispatch; PR 4 replaced the per-event payload-dict densification walk with
columnar numpy (8.5x densify events/s at 512-event chunks).  Both
regressions re-enter the codebase the same way: an innocent ``for ev in
events`` or ``ev.payload().items()`` inside a densify function, correct
and quietly 10x slower.  This rule makes the loop itself the violation.

Scope (project model): the union of functions whose NAME contains
``densify``/``dispatch`` plus the hot routing helpers
(``_chunk_layout``/``_pack_columnar``) -- the pre-project textual
scoping, kept so a hot-named function with no resolvable caller is still
covered -- and everything in :meth:`Project.hot_path`: transitive callees
of the engine ``densify``/``dispatch``/``consume`` entry points, resolved
through the call graph.  The reachability half is what closes the
wrapper-indirection false negative: a per-event walk in an innocently
named helper called from ``consume_groups`` is on the hot path whatever
it is called.  Both halves restricted to ``repro.etl`` and
``repro.kernels`` files.

Per-COLUMN and per-SHARD/per-BLOCK loops are fine (columns and shards are
few and bounded); what is flagged is iteration whose trip count scales
with the chunk: loops over events/items and any ``.payload()`` call (the
dict-walk marker).  The deliberate per-event paths carry function-level
waivers on their ``def`` lines: the dict-walk oracle
(:func:`repro.etl.engines.densify_chunk_dicts`), the legacy ``Groups``
lift at the consume boundary (:func:`repro.etl.engines.as_triaged`) and
the source-boundary payload flatten
(:func:`repro.etl.events.columnarize`).
"""

from __future__ import annotations

import ast
import re
from typing import Iterator, Sequence, Set, Tuple

from ..core import FileCtx, Finding, Rule, register
from ..project import as_project

_HOT_NAME = re.compile(r"densify|dispatch|_chunk_layout|_pack_columnar")

# iterable source text that scales with the chunk's event/item count
_EVENTISH = re.compile(
    r"\bevents\b|\bevs\b|\.payload\(|chunk\.keys|chunk\.uids|chunk\.vals"
    r"|chunk\.events|\bitem_idx\b|\bev_rows\b"
)


@register
class HotPathPythonLoop(Rule):
    id = "hot-path-python-loop"
    title = "no per-event python loops / payload-dict walks in densify or dispatch"
    motivation = (
        "the PR-1 (per-block python mapping walk) and PR-4 (per-event "
        "payload-dict densify walk, 8.5x once vectorised) regression class"
    )

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        project = as_project(ctxs)
        hot = project.hot_path()
        seen: Set[Tuple[str, int]] = set()
        # reachability half: functions on the hot path through the call
        # graph, whatever their name
        for qname in sorted(hot):
            info = project.functions[qname]
            if self._in_scope(info.ctx):
                seen.add((info.ctx.rel, info.node.lineno))
                yield from self._check_fn(info.ctx, info.node)
        # textual half: hot-NAMED functions the call graph could not reach
        # (an entry point nothing analyzed calls yet is still hot)
        for ctx in ctxs:
            if not self._in_scope(ctx):
                continue
            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if _HOT_NAME.search(node.name) and (ctx.rel, node.lineno) not in seen:
                        yield from self._check_fn(ctx, node)

    @staticmethod
    def _in_scope(ctx: FileCtx) -> bool:
        return ctx.in_package("repro", "etl") or ctx.in_package("repro", "kernels")

    def _check_fn(self, ctx: FileCtx, fn: ast.FunctionDef) -> Iterator[Finding]:
        where = f"in hot-path function {fn.name}()"
        for node in ast.walk(fn):
            # the dict-walk marker: ANY payload() call means per-event dicts
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "payload"
            ):
                yield ctx.finding(
                    self.id,
                    node,
                    f".payload() {where}: per-event payload-dict walk "
                    "(the PR-4 regression); densify from the chunk's "
                    "columnar uids/vals arrays instead",
                )
                continue
            iters = []
            if isinstance(node, ast.For):
                iters = [node.iter]
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)):
                iters = [g.iter for g in node.generators]
            for it in iters:
                src = ctx.segment(it)
                if _EVENTISH.search(src):
                    yield ctx.finding(
                        self.id,
                        node,
                        f"python loop over '{src}' {where} scales with the "
                        "chunk's event/item count; vectorise it (see "
                        "_segmented_arange / _event_items) or waive with a "
                        "reason if it is an oracle path",
                    )
                    break
