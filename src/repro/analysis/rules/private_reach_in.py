"""private-reach-in: no private METLApp/engine/Registry access outside the
owning package (the AST successor of the two ci.sh ``git grep`` gates).

The grep gates had three failure modes this rule closes:

  * **aliases** -- ``shadow = app; shadow._fused`` never contains the
    literal ``app._`` and slipped the first grep; the rule tracks names
    bound to app/engine/registry values through assignments, annotations
    and call results, so the alias is as private as the original;
  * **strings/comments** -- docstrings describing ``app._fused`` tripped
    regexes; an AST attribute node cannot be a comment;
  * **receiver blindness** -- ``registry._[a-z]`` missed receivers named
    anything else; the rule types receivers, and keeps the known private
    attribute names (``._fused``, ``._seen``, ...) as an any-receiver
    backstop exactly like the second grep pattern did.

Ownership: METLApp/engine internals belong to ``repro.etl``; Registry
internals belong to ``repro.core``.  Files inside the owning package are
exempt; ``self.`` access is always exempt.

Project model: constructor calls and annotations additionally resolve
through the file's import table (``FileCtx.module``), so ``from
repro.etl.metl import METLApp as App; a = App(...)`` types ``a`` exactly
like the unaliased name -- the one alias form the original rule still
missed.
"""

from __future__ import annotations

import ast
from typing import Any, Dict, Iterator, Optional, Set

from ..core import FileCtx, Finding, Rule, register

# receiver kinds and the package that owns their privates
_OWNER = {
    "app": ("repro", "etl"),
    "engine": ("repro", "etl"),
    "registry": ("repro", "core"),
}

_PUBLIC_API = {
    "app": "app.engine.info() / app.reset_dedup() / app.consume()",
    "engine": "engine.info()",
    "registry": "coordinator.apply(ControlEvent) / Registry.bump_state()",
}

# constructors / factories whose result has a known kind
_CALL_KINDS = {
    "METLApp": "app",
    "Registry": "registry",
    "make_engine": "engine",
    "MappingEngine": "engine",
    "FusedEngine": "engine",
    "ShardedEngine": "engine",
    "BlocksEngine": "engine",
}

# annotation names -> kind (params and AnnAssign)
_ANNOT_KINDS = {
    "METLApp": "app",
    "Registry": "registry",
    "MappingEngine": "engine",
    "FusedEngine": "engine",
    "ShardedEngine": "engine",
    "BlocksEngine": "engine",
}

# the known METLApp/engine private names, on ANY receiver -- the backstop
# pattern the old second grep used (catches app_rep._fused, shd._sharded)
_KNOWN_APP_PRIVATE = frozenset(
    {
        "_fused",
        "_sharded",
        "_compiled",
        "_seen",
        "_parked",
        "_replay_rows",
        "_snapshot",
        "_dedup_window",
        "_is_duplicate",
    }
)


def _name_hint(name: str) -> Optional[str]:
    """Conventional-name fallback for unannotated, untracked receivers."""
    if name == "app" or name.startswith("app_") or name.endswith("_app"):
        return "app"
    if name == "registry" or name.endswith("_registry"):
        return "registry"
    if name == "engine" or name.endswith("_engine"):
        return "engine"
    return None


def _resolved_kind(name: str, module: Any, table: Dict[str, str]) -> Optional[str]:
    """Map a local name through the kind table, resolving import aliases
    via the project model's module import table when one is attached."""
    kind = table.get(name)
    if kind is not None:
        return kind
    if module is not None:
        qname = module.resolve(name)
        if qname is not None:
            return table.get(qname.rsplit(".", 1)[-1])
    return None


def _annot_kind(node: Optional[ast.expr], module: Any = None) -> Optional[str]:
    if isinstance(node, ast.Name):
        return _resolved_kind(node.id, module, _ANNOT_KINDS)
    if isinstance(node, ast.Attribute):
        return _ANNOT_KINDS.get(node.attr)
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return _ANNOT_KINDS.get(node.value.strip().rsplit(".", 1)[-1])
    return None


class _Scope:
    def __init__(self, parent: Optional["_Scope"] = None) -> None:
        self.parent = parent
        self.kinds: Dict[str, str] = {}

    def get(self, name: str) -> Optional[str]:
        scope: Optional[_Scope] = self
        while scope is not None:
            if name in scope.kinds:
                return scope.kinds[name]
            scope = scope.parent
        return _name_hint(name)

    def set(self, name: str, kind: Optional[str]) -> None:
        if kind is None:
            # an explicit rebind to an unknown value clears the tracking
            self.kinds.pop(name, None)
        else:
            self.kinds[name] = kind


@register
class PrivateReachIn(Rule):
    id = "private-reach-in"
    title = "no private METLApp/engine/Registry access outside the owner"
    motivation = (
        "PR 3/PR 5 moved launchers and benchmarks onto the public engine "
        "protocol; the grep gates that enforced it missed aliases and "
        "false-positived on docstrings"
    )

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        exempt = {
            kind for kind, pkg in _OWNER.items() if ctx.in_package(*pkg)
        }
        if len(exempt) == len(_OWNER):
            return
        yield from self._visit(ctx, ctx.tree, _Scope(), exempt)

    # -- scoped walk ----------------------------------------------------------
    def _infer(self, ctx: FileCtx, scope: _Scope, node: ast.expr) -> Optional[str]:
        if isinstance(node, ast.Name):
            return scope.get(node.id)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name):
                return _resolved_kind(fn.id, ctx.module, _CALL_KINDS)
            if isinstance(fn, ast.Attribute):
                return _CALL_KINDS.get(fn.attr)
        if isinstance(node, ast.Attribute):
            # pipeline.app, cluster.apps[0].engine, ... -- type by the
            # conventional attribute name (public attrs only)
            if not node.attr.startswith("_"):
                return _name_hint(node.attr)
        return None

    def _bind(self, scope: _Scope, target: ast.expr, kind: Optional[str]) -> None:
        if isinstance(target, ast.Name):
            scope.set(target.id, kind)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind(scope, el, None)

    def _visit(
        self, ctx: FileCtx, node: ast.AST, scope: _Scope, exempt: Set[str]
    ) -> Iterator[Finding]:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = _Scope(scope)
            args = node.args
            for a in args.posonlyargs + args.args + args.kwonlyargs:
                kind = _annot_kind(a.annotation, ctx.module)
                if kind is not None:
                    inner.set(a.arg, kind)
            for child in node.body:
                yield from self._visit(ctx, child, inner, exempt)
            return
        if isinstance(node, ast.ClassDef):
            inner = _Scope(scope)
            for child in node.body:
                yield from self._visit(ctx, child, inner, exempt)
            return
        if isinstance(node, ast.Attribute):
            yield from self._check_attr(ctx, node, scope, exempt)
            yield from self._visit(ctx, node.value, scope, exempt)
            return
        if isinstance(node, ast.Assign):
            yield from self._visit(ctx, node.value, scope, exempt)
            kind = self._infer(ctx, scope, node.value)
            for t in node.targets:
                self._bind(scope, t, kind)
            return
        if isinstance(node, ast.AnnAssign):
            if node.value is not None:
                yield from self._visit(ctx, node.value, scope, exempt)
            kind = _annot_kind(node.annotation, ctx.module)
            if kind is None and node.value is not None:
                kind = self._infer(ctx, scope, node.value)
            if isinstance(node.target, ast.Name):
                scope.set(node.target.id, kind)
            return
        for child in ast.iter_child_nodes(node):
            yield from self._visit(ctx, child, scope, exempt)

    def _check_attr(
        self, ctx: FileCtx, node: ast.Attribute, scope: _Scope, exempt: Set[str]
    ) -> Iterator[Finding]:
        attr = node.attr
        if not attr.startswith("_") or attr.startswith("__"):
            return
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            return
        kind = self._infer(ctx, scope, node.value)
        if kind is None and attr in _KNOWN_APP_PRIVATE:
            kind = "app"  # any-receiver backstop (old grep pattern 2)
        if kind is None or kind in exempt:
            return
        recv = ctx.segment(node.value) or "<expr>"
        yield ctx.finding(
            self.id,
            node,
            f"private {kind} attribute {recv}.{attr} reached from outside "
            f"{'.'.join(_OWNER[kind])}; use {_PUBLIC_API[kind]}",
        )
