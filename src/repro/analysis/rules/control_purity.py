"""control-plane-purity: the single-writer control plane stays single-writer.

PR 5 made schema changes typed, in-band control events whose registry
mutation runs ONLY inside :meth:`StateCoordinator.apply` -- that is the
whole replayability story: ``apply`` appends every applied event to the
epoch-ordered ``control_log``, so replaying the log over a seed registry
reconstructs state bit-exactly.  A ``event.mutate(registry)`` call
anywhere else mutates the registry *without* logging it, silently breaking
log replay (a fresh instance joining from the log would diverge).
Likewise, a mutable ControlEvent subclass lets a caller edit an event
after it was logged, corrupting the already-written history.

Two checks:

  * ``.mutate(...)`` may be called only inside ``StateCoordinator.apply``;
  * every class deriving (transitively, within a file) from
    ``ControlEvent`` must be decorated ``@dataclasses.dataclass(frozen=
    True)``.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from ..core import FileCtx, Finding, Rule, register


def _dataclass_frozen(dec: ast.expr) -> bool:
    """True for @dataclass(frozen=True) / @dataclasses.dataclass(frozen=True)."""
    if not isinstance(dec, ast.Call):
        return False
    f = dec.func
    name = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else None
    )
    if name != "dataclass":
        return False
    for kw in dec.keywords:
        if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


@register
class ControlPlanePurity(Rule):
    id = "control-plane-purity"
    title = "mutate() only inside StateCoordinator.apply; ControlEvents frozen"
    motivation = (
        "PR 5's control_log replay is bit-exact only because every registry "
        "mutation is logged by the one writer; an unlogged mutate() or a "
        "mutable logged event silently corrupts replay"
    )

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        yield from self._check_mutate_calls(ctx)
        yield from self._check_frozen_events(ctx)

    # -- check 1: .mutate() call sites ---------------------------------------
    def _check_mutate_calls(self, ctx: FileCtx) -> Iterator[Finding]:
        for cls, fn, node in _calls_with_context(ctx.tree):
            if not (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "mutate"
            ):
                continue
            if cls == "StateCoordinator" and fn == "apply":
                continue
            where = f"{cls}.{fn}" if cls else (fn or "<module>")
            yield ctx.finding(
                self.id,
                node,
                f".mutate() called from {where}: registry mutations must go "
                "through StateCoordinator.apply(event) so they land in the "
                "replayable control_log",
            )

    # -- check 2: ControlEvent subclasses are frozen dataclasses --------------
    def _check_frozen_events(self, ctx: FileCtx) -> Iterator[Finding]:
        # transitive within the file: class X(ControlEvent) seeds, then
        # class Y(X) inherits the obligation
        event_classes: Set[str] = {"ControlEvent"}
        classes = [
            n for n in ast.walk(ctx.tree) if isinstance(n, ast.ClassDef)
        ]
        changed = True
        while changed:
            changed = False
            for cls in classes:
                if cls.name in event_classes:
                    continue
                for base in cls.bases:
                    base_name = (
                        base.id
                        if isinstance(base, ast.Name)
                        else base.attr if isinstance(base, ast.Attribute) else None
                    )
                    if base_name in event_classes:
                        event_classes.add(cls.name)
                        changed = True
                        break
        for cls in classes:
            if cls.name not in event_classes or cls.name == "ControlEvent":
                continue
            if not any(_dataclass_frozen(d) for d in cls.decorator_list):
                yield ctx.finding(
                    self.id,
                    cls,
                    f"ControlEvent subclass {cls.name} is not a frozen "
                    "dataclass; logged events must be immutable "
                    "(@dataclasses.dataclass(frozen=True))",
                )


def _calls_with_context(tree: ast.Module):
    """Yield (enclosing_class, enclosing_function, Call) for every call."""

    def walk(node, cls, fn):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name, fn)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from walk(child, cls, child.name)
            else:
                if isinstance(child, ast.Call):
                    yield (cls, fn, child)
                yield from walk(child, cls, fn)

    yield from walk(tree, None, None)
