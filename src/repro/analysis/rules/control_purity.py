"""control-plane-purity: the single-writer control plane stays single-writer.

PR 5 made schema changes typed, in-band control events whose registry
mutation runs ONLY inside :meth:`StateCoordinator.apply` -- that is the
whole replayability story: ``apply`` appends every applied event to the
epoch-ordered ``control_log``, so replaying the log over a seed registry
reconstructs state bit-exactly.  A ``event.mutate(registry)`` call
anywhere else mutates the registry *without* logging it, silently breaking
log replay (a fresh instance joining from the log would diverge).
Likewise, a mutable ControlEvent subclass lets a caller edit an event
after it was logged, corrupting the already-written history.

Two checks:

  * ``.mutate(...)`` may be called only inside ``StateCoordinator.apply``
    -- resolved through the call graph, not textual match: a private
    helper whose every caller chain terminates at ``apply``
    (:meth:`Project.only_called_from`) inherits the privilege, so
    ``apply`` can be refactored into steps without waivers, while a
    helper also reachable from public code is refused;
  * every class deriving (transitively, within a file) from
    ``ControlEvent`` must be decorated ``@dataclasses.dataclass(frozen=
    True)``.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Sequence, Set, Tuple

from ..core import FileCtx, Finding, Rule, register
from ..project import as_project


def _dataclass_frozen(dec: ast.expr) -> bool:
    """True for @dataclass(frozen=True) / @dataclasses.dataclass(frozen=True)."""
    if not isinstance(dec, ast.Call):
        return False
    f = dec.func
    name = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else None
    )
    if name != "dataclass":
        return False
    for kw in dec.keywords:
        if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


@register
class ControlPlanePurity(Rule):
    id = "control-plane-purity"
    title = "mutate() only inside StateCoordinator.apply; ControlEvents frozen"
    motivation = (
        "PR 5's control_log replay is bit-exact only because every registry "
        "mutation is logged by the one writer; an unlogged mutate() or a "
        "mutable logged event silently corrupts replay"
    )

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        yield from self._check_frozen_events(ctx)
        # module-level .mutate() calls: no enclosing function, so the call
        # graph has nothing to resolve -- always a violation
        for cls, fn, node in _calls_with_context(ctx.tree):
            if fn is None and self._is_mutate(node):
                yield self._mutate_finding(ctx, node, cls or "<module>")

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        # check 1, resolved through the call graph: .mutate() only inside
        # StateCoordinator.apply or a private helper of it
        project = as_project(ctxs)
        apply_qnames = {
            info.qname
            for info in project.functions.values()
            if info.cls == "StateCoordinator" and info.name == "apply"
        }
        for info in project.functions.values():
            if info.qname in apply_qnames:
                continue
            if apply_qnames and any(
                project.only_called_from(info.qname, a) for a in apply_qnames
            ):
                continue
            for node in ast.walk(info.node):
                if isinstance(node, ast.Call) and self._is_mutate(node):
                    where = f"{info.cls}.{info.name}" if info.cls else info.name
                    yield self._mutate_finding(info.ctx, node, where)

    @staticmethod
    def _is_mutate(node: ast.Call) -> bool:
        return isinstance(node.func, ast.Attribute) and node.func.attr == "mutate"

    def _mutate_finding(self, ctx: FileCtx, node: ast.Call, where: str) -> Finding:
        return ctx.finding(
            self.id,
            node,
            f".mutate() called from {where}: registry mutations must go "
            "through StateCoordinator.apply(event) so they land in the "
            "replayable control_log",
        )

    # -- check 2: ControlEvent subclasses are frozen dataclasses --------------
    def _check_frozen_events(self, ctx: FileCtx) -> Iterator[Finding]:
        # transitive within the file: class X(ControlEvent) seeds, then
        # class Y(X) inherits the obligation
        event_classes: Set[str] = {"ControlEvent"}
        classes = [
            n for n in ast.walk(ctx.tree) if isinstance(n, ast.ClassDef)
        ]
        changed = True
        while changed:
            changed = False
            for cls in classes:
                if cls.name in event_classes:
                    continue
                for base in cls.bases:
                    base_name = (
                        base.id
                        if isinstance(base, ast.Name)
                        else base.attr if isinstance(base, ast.Attribute) else None
                    )
                    if base_name in event_classes:
                        event_classes.add(cls.name)
                        changed = True
                        break
        for cls in classes:
            if cls.name not in event_classes or cls.name == "ControlEvent":
                continue
            if not any(_dataclass_frozen(d) for d in cls.decorator_list):
                yield ctx.finding(
                    self.id,
                    cls,
                    f"ControlEvent subclass {cls.name} is not a frozen "
                    "dataclass; logged events must be immutable "
                    "(@dataclasses.dataclass(frozen=True))",
                )


def _calls_with_context(
    tree: ast.Module,
) -> Iterator[Tuple[Optional[str], Optional[str], ast.Call]]:
    """Yield (enclosing_class, enclosing_function, Call) for every call."""

    def walk(
        node: ast.AST, cls: Optional[str], fn: Optional[str]
    ) -> Iterator[Tuple[Optional[str], Optional[str], ast.Call]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name, fn)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from walk(child, cls, child.name)
            else:
                if isinstance(child, ast.Call):
                    yield (cls, fn, child)
                yield from walk(child, cls, fn)

    yield from walk(tree, None, None)
