"""host-sync-in-hot-path: no implicit device synchronisation inside the
async consume machinery.

The engine contract (engines.py / ops.py docstrings) is that ``dispatch``
returns UNBLOCKED jax arrays and ``emit`` is the ONE deliberate sync point
-- that asymmetry is what lets the pipeline's double-buffered async consume
overlap chunk N+1's host densification with chunk N's device execution
(PR 3), and what the device-densify path's one-transfer-per-chunk claim
rests on (PR 6).  A stray ``np.asarray``/``.block_until_ready()``/
``float(handle...)`` anywhere in ``dispatch``/``_run_async`` silently
serialises the whole overlap; one in ``emit`` is fine but must be
*annotated* so the next reader (and this rule) can tell the deliberate
sync point from an accident:

    ov = np.asarray(handle.outputs[0])[:s]  # metl: allow[host-sync-in-hot-path] the engine sync point

Scope: functions named ``dispatch`` / ``emit`` / ``_run_async`` and the
``dmm_apply*`` wrappers, in the ``repro.etl`` and ``repro.kernels``
packages -- checked with the full strict/lenient heuristics -- PLUS
(project model) every function *reachable* from a ``dispatch`` /
``dmm_apply*`` seed through the call graph, which closes the
wrapper-indirection hole: hoisting a ``np.asarray`` into an innocently
named helper called from dispatch used to hide it from this rule.
Reached helpers are checked against the EXPLICIT sync set only
(np/jax sync calls and ``.block_until_ready()``); the scalar-read
heuristics (``.item()``, ``float(x[...])``) stay name-scoped because a
general helper legitimately does host-scalar work that dispatch itself
must not.  ``_run_async`` deliberately does not seed reachability: its
callees include the whole densify subtree, whose host-numpy work is the
thing the async overlap hides.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator, Sequence, Set, Tuple

from ..core import FileCtx, Finding, Rule, register
from ..project import as_project

_HOT_NAME = re.compile(r"^(dispatch|emit|_run_async|dmm_apply\w*)$")
_REACH_SEED = re.compile(r"^(dispatch|dmm_apply\w*)$")

# np-namespace calls that force a host readback of their operand
_NP_SYNC = frozenset({"asarray", "array", "ascontiguousarray", "copyto"})
# method calls that block on / read back a device array
_METHOD_SYNC = frozenset({"block_until_ready", "item", "tolist", "copy_to_host"})
# jax-namespace calls that block
_JAX_SYNC = frozenset({"device_get", "block_until_ready"})


@register
class HostSyncInHotPath(Rule):
    id = "host-sync-in-hot-path"
    title = "no implicit device sync inside dispatch/_run_async; emit's sync is annotated"
    motivation = (
        "PR 3's async double buffer and PR 6's one-transfer-per-chunk "
        "contract both die silently if a host readback sneaks into the "
        "dispatch path (the regression is invisible: results stay correct, "
        "the overlap just stops)"
    )

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        if not self._in_scope(ctx):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _HOT_NAME.match(node.name):
                    yield from self._check_region(ctx, node)

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        # helpers REACHED from dispatch/dmm_apply* (not name-matched --
        # those already ran the full heuristics in check_file): flag the
        # explicit sync calls only
        project = as_project(ctxs)
        seeds = project.seeds_matching(
            _REACH_SEED, packages=(("repro", "etl"), ("repro", "kernels"))
        )
        for qname in sorted(project.reachable(seeds)):
            info = project.functions[qname]
            if _HOT_NAME.match(info.name) or not self._in_scope(info.ctx):
                continue
            yield from self._check_explicit(info.ctx, info.node)

    @staticmethod
    def _in_scope(ctx: FileCtx) -> bool:
        return ctx.in_package("repro", "etl") or ctx.in_package("repro", "kernels")

    def _check_explicit(self, ctx: FileCtx, fn: ast.FunctionDef) -> Iterator[Finding]:
        where = f"in {fn.name}(), reachable from the dispatch path"
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not isinstance(f, ast.Attribute):
                continue
            recv = f.value
            if (
                isinstance(recv, ast.Name)
                and recv.id in ("np", "numpy")
                and f.attr in _NP_SYNC
            ):
                yield ctx.finding(
                    self.id,
                    node,
                    f"np.{f.attr}() {where} forces a host readback; the "
                    "dispatch path must stay unblocked end-to-end (sync "
                    "belongs in emit, annotated "
                    "'# metl: allow[host-sync-in-hot-path] ...')",
                )
            elif (
                isinstance(recv, ast.Name)
                and recv.id == "jax"
                and f.attr in _JAX_SYNC
            ):
                yield ctx.finding(
                    self.id, node, f"jax.{f.attr}() {where} blocks on the device"
                )
            elif f.attr == "block_until_ready":
                yield ctx.finding(
                    self.id,
                    node,
                    f".block_until_ready() {where} blocks on its receiver; "
                    "keep the dispatch handle unblocked",
                )

    def _check_region(self, ctx: FileCtx, fn: ast.FunctionDef) -> Iterator[Finding]:
        where = f"in hot-path function {fn.name}()"
        # emit is post-sync host code: only the readback ENTRY points need an
        # annotation there.  dispatch/_run_async/dmm_apply* must never touch
        # device values at all, so scalar reads (.item/float(x[0])) are also
        # flagged -- in emit they are routine host-numpy work.
        strict = fn.name != "emit"
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                recv = f.value
                if (
                    isinstance(recv, ast.Name)
                    and recv.id in ("np", "numpy")
                    and f.attr in _NP_SYNC
                ):
                    yield ctx.finding(
                        self.id,
                        node,
                        f"np.{f.attr}() {where} forces a host readback; "
                        "dispatch must stay unblocked (sync belongs in emit, "
                        "annotated '# metl: allow[host-sync-in-hot-path] ...')",
                    )
                elif (
                    isinstance(recv, ast.Name)
                    and recv.id == "jax"
                    and f.attr in _JAX_SYNC
                ):
                    yield ctx.finding(
                        self.id, node, f"jax.{f.attr}() {where} blocks on the device"
                    )
                elif f.attr == "block_until_ready" or (
                    strict and f.attr in _METHOD_SYNC
                ):
                    yield ctx.finding(
                        self.id,
                        node,
                        f".{f.attr}() {where} blocks on / reads back its "
                        "receiver; keep the dispatch handle unblocked",
                    )
            elif strict and isinstance(f, ast.Name) and f.id in ("float", "int", "bool"):
                # float(x) on a python scalar is fine; float(handle.outputs[0])
                # or float(arr[0]) is a one-element device readback
                if node.args and isinstance(
                    node.args[0], (ast.Attribute, ast.Subscript)
                ):
                    target = ctx.segment(node.args[0])
                    yield ctx.finding(
                        self.id,
                        node,
                        f"{f.id}({target}) {where} is a scalar device "
                        "readback if the operand is a device handle; hoist "
                        "it out of the hot path or annotate the sync point",
                    )
