"""epoch-pin-escape: in-flight dense chunks carry their epoch pin and are
not read through across a coordinator mutation.

PR 5 made in-flight chunks epoch-pinned: ``DenseChunk``/``ColumnarDense``
hold the ``plan`` they were densified under, so a chunk dispatched before
a control event drains on the OLD table while the coordinator moves on --
that is the whole correctness story for applying control at chunk
boundaries (and the mechanism the ROADMAP's online-compaction item
publishes new plans through).  The pin escapes two ways, both silent:

  * a construction that drops the pin (``ColumnarDense(plan=None, ...)``
    or no ``plan`` at all) produces a chunk whose ``.epoch``/table
    resolution follows the *live* plan;
  * reading plan state THROUGH a chunk (``chunk.plan...`` or
    ``chunk.epoch``) after a coordinator mutation in the same scope: the
    read observes post-mutation state for a chunk densified pre-mutation.

Checks: every ``DenseChunk``/``ColumnarDense`` call (resolved through
imports; ``dataclasses.replace`` is exempt) must bind ``plan`` positionally
or by keyword, to something other than ``None``; and in each function,
a ``.plan``/``.epoch`` load through a variable bound from ``.densify()``
or a chunk constructor is flagged when a coordinator mutation
(``.apply``/``.freeze``/``.thaw``/``.apply_update``/``.set_dpm`` on a
coordinator-typed receiver) sits between the bind and the read --
rebinding the chunk after the mutation re-pins it and clears the flag.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence

from ..core import FileCtx, Finding, Rule, register
from ..project import FunctionInfo, Project, as_project, attr_chain

_CHUNK_TYPES = frozenset({"DenseChunk", "ColumnarDense"})
_MUTATORS = frozenset({"apply", "freeze", "thaw", "apply_update", "set_dpm"})


def _chunk_ctor(func: ast.expr) -> Optional[str]:
    """The chunk type name when ``func`` is a DenseChunk/ColumnarDense
    reference (possibly dotted / aliased by import handled by caller)."""
    chain = attr_chain(func)
    if chain is None:
        return None
    tail = chain.split(".")[-1]
    return tail if tail in _CHUNK_TYPES else None


def _coordinatorish(chain: Optional[str]) -> bool:
    if chain is None:
        return False
    leaf = chain.split(".")[-1]
    return (
        leaf in ("coordinator", "coord")
        or leaf.endswith("_coordinator")
        or leaf.endswith("_coord")
    )


@register
class EpochPinEscape(Rule):
    id = "epoch-pin-escape"
    title = "dense chunks carry their epoch pin; no plan read through a chunk across a mutation"
    motivation = (
        "PR 5's chunk-boundary control application is only correct because "
        "in-flight chunks are pinned to the plan they were densified under; "
        "an unpinned chunk or a post-mutation read through one follows the "
        "live plan and maps rows with the wrong table"
    )

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        project = as_project(ctxs)
        for info in project.functions.values():
            yield from self._check_ctors(project, info)
            yield from self._check_cross_mutation_reads(info)

    # -- check 1: every construction binds the pin ----------------------------
    def _check_ctors(self, project: Project, info: FunctionInfo) -> Iterator[Finding]:
        ctx = info.ctx
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            tname = _chunk_ctor(node.func)
            if tname is None:
                # import alias: From x import ColumnarDense as CD
                chain = attr_chain(node.func)
                if chain is not None and info.module is not None:
                    q = info.module.resolve(chain)
                    if q is not None and q.split(".")[-1] in _CHUNK_TYPES:
                        tname = q.split(".")[-1]
            if tname is None:
                continue
            plan: Optional[ast.expr] = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "plan":
                    plan = kw.value
                if kw.arg is None:
                    plan = plan or kw.value  # **kwargs: assume it carries plan
            if plan is None:
                yield ctx.finding(
                    self.id,
                    node,
                    f"{tname}(...) constructed without its epoch pin in "
                    f"{info.name}(): pass plan= so the in-flight chunk drains "
                    "on the table it was densified under",
                )
            elif isinstance(plan, ast.Constant) and plan.value is None:
                yield ctx.finding(
                    self.id,
                    node,
                    f"{tname}(plan=None, ...) in {info.name}() drops the "
                    "epoch pin: the chunk would resolve against the live "
                    "plan after the next control event",
                )

    # -- check 2: no plan read through a chunk across a mutation --------------
    def _check_cross_mutation_reads(self, info: FunctionInfo) -> Iterator[Finding]:
        ctx = info.ctx

        chunk_binds: Dict[str, List[int]] = {}
        for node in ast.walk(info.node):
            if not (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)):
                continue
            fchain = attr_chain(node.value.func) or ""
            tail = fchain.split(".")[-1]
            if tail == "densify" or tail in _CHUNK_TYPES:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        chunk_binds.setdefault(tgt.id, []).append(node.lineno)
        if not chunk_binds:
            return

        mutations: List[int] = []
        for node in ast.walk(info.node):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _MUTATORS
                and _coordinatorish(attr_chain(node.func.value))
            ):
                mutations.append(node.lineno)
        if not mutations:
            return

        for node in ast.walk(info.node):
            if not (
                isinstance(node, ast.Attribute)
                and node.attr in ("plan", "epoch")
                and isinstance(node.ctx, ast.Load)
                and isinstance(node.value, ast.Name)
                and node.value.id in chunk_binds
            ):
                continue
            binds = [b for b in chunk_binds[node.value.id] if b <= node.lineno]
            if not binds:
                continue
            last_bind = max(binds)
            crossed = [m for m in mutations if last_bind < m <= node.lineno]
            if not crossed:
                continue
            yield ctx.finding(
                self.id,
                node,
                f"{node.value.id}.{node.attr} read after a coordinator "
                f"mutation on line {crossed[0]} in {info.name}(): the chunk "
                f"was densified before the mutation (line {last_bind}), so "
                "plan state read through it is no longer the pinned epoch -- "
                "capture it before applying control, or re-densify",
            )
