"""plan-publish-single-site: fused plans have ONE construction/publish site.

PR 9's epoched plan lifecycle moves every fused-plan build behind
``repro.etl.plan.PlanManager``: ``acquire``/``repartition`` are the only
doors, ``_install`` is the only place a ``PlanPublished`` control event is
cut, and the lowering primitives (``compile_fused`` /
``compile_fused_sharded`` / ``recompile_columns`` / ``splice_fused`` and
the ``FusedDMM`` / ``ShardedFusedDMM`` constructors) belong to
``repro.core.dmm_jax``.  A plan built anywhere else is an unmanaged epoch:
it carries no epoch number, its residency skips the tiering policy, its
cutover is never published for replay, and the manager's ``rebuilds`` /
``bytes_resident`` accounting silently lies.  The incremental/full
bit-exactness contract is only enforced on builds the manager performs.

Like ``single-writer-control``, the name is the contract: a call whose
(import-resolved) target name is one of the restricted symbols fires on
any receiver, so ``dmm_jax.compile_fused(...)``, a ``from ... import
compile_fused as cf`` alias, and a bare ``compile_fused(...)`` are all the
same finding.  ``compile_dpm`` is deliberately NOT restricted -- the
host-side compacted form is a free intermediate (benchmarks A/B it
directly); only the device-resident fused lowering and the publish event
are single-site.

Exempt: ``repro.core.dmm_jax`` (the lowering layer itself) and
``repro.etl.plan`` (the manager).  Tests exercise the primitives directly
through their own sweep, which does not select this rule.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from ..core import FileCtx, Finding, Rule, register
from ..project import attr_chain, module_name

_RESTRICTED = frozenset(
    {
        "compile_fused",
        "compile_fused_sharded",
        "recompile_columns",
        "splice_fused",
        "FusedDMM",
        "ShardedFusedDMM",
        "PlanPublished",
    }
)
_OWNERS = ("repro.core.dmm_jax", "repro.etl.plan")


def _target_name(ctx: FileCtx, func: ast.expr) -> Optional[str]:
    """The restricted symbol a call targets, or None.

    Checks the raw dotted chain's tail AND the import-resolved qname's
    tail, so both ``dmm_jax.compile_fused(...)`` and an aliased
    ``cf(...)`` (``from ... import compile_fused as cf``) resolve.
    """
    chain = attr_chain(func)
    if chain is None:
        return None
    tail = chain.split(".")[-1]
    if tail in _RESTRICTED:
        return tail
    mod = getattr(ctx, "module", None)
    if mod is not None:
        resolved = mod.resolve(chain)
        if resolved:
            rtail = resolved.split(".")[-1]
            if rtail in _RESTRICTED:
                return rtail
    return None


@register
class PlanPublishSingleSite(Rule):
    id = "plan-publish-single-site"
    title = "only PlanManager (repro.etl.plan) builds/publishes fused plans"
    motivation = (
        "PR 9's epoch counter, tiering residency, rebuild accounting and "
        "PlanPublished replay all hang off one build path; a plan "
        "constructed elsewhere is an unmanaged epoch that dodges every "
        "one of those contracts"
    )

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        if module_name(ctx) in _OWNERS:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _target_name(ctx, node.func)
            if name is None:
                continue
            yield ctx.finding(
                self.id,
                node,
                f"{name}(...) outside {' / '.join(_OWNERS)}: fused plans "
                "have one construction/publish site -- acquire an epoch "
                "lease through PlanManager.acquire/repartition (or "
                "PlanManager.repartition for a residency re-cut) instead "
                "of lowering or publishing a plan by hand",
            )
