"""single-writer-control: only ``StateCoordinator.apply`` writes the control
plane.

PR 5's replayability story has ONE writer: ``StateCoordinator.apply``
applies a control event, appends the ``ControlRecord`` to ``control_log``
and advances ``_dpm``/``_frozen``/``_deferred`` -- replaying the log over
a seed registry reconstructs state bit-exactly, which is what the PR 5
cluster (and the ROADMAP's distributed coordinator, where the log IS the
replication transport) rely on.  ``control-plane-purity`` already pins
``event.mutate()`` call sites; this rule pins the *state itself*: an
append to ``control_log`` or an assignment to coordinator state from
anywhere else produces unlogged history -- a follower replaying the log
diverges silently.

Resolution is through the call graph, not textual match: a helper is
allowed to write iff every one of its caller chains terminates at
``StateCoordinator.apply`` (:meth:`Project.only_called_from`) -- so
``apply`` can be refactored into private steps without waivers, while a
"wrapper" also reachable from public code is correctly refused.

Checks (project-wide):

  * mutating method calls on ``control_log`` (``.append``/``.extend``/
    ``.insert``/``.pop``/``.remove``/``.clear``) and assignments to a
    ``control_log`` attribute, on ANY receiver -- the name is the contract;
  * assignments/augmented assignments to ``._dpm``/``._frozen``/
    ``._deferred`` on a coordinator-typed receiver (``self`` inside
    ``StateCoordinator``, names bound from ``StateCoordinator(...)`` or
    conventionally named ``coordinator``/``*_coord``, or attribute chains
    ending ``.coordinator``).

``__init__`` constructs the state and is exempt alongside ``apply``.
Reading any of these (``len(coordinator.control_log)``, replay) is free.

**Replication scope (PR 10).**  The distributed control plane
(:mod:`repro.etl.replication` / :mod:`repro.etl.transport`) splits the
single writer across processes: the LEADER path owns
``StateCoordinator.apply``; follower code rebuilds state exclusively
through ``replay_control_log(..., coordinator=...)``.  Inside those two
modules this rule therefore also flags ``.apply()`` / ``.apply_update()``
calls on any coordinator-typed receiver outside the ``LeaderNode`` class
-- a follower (or transport helper) applying directly would produce
writes the replicated log never shipped, the cross-process version of
unlogged history.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Sequence, Set, Tuple

from ..core import FileCtx, Finding, Rule, register
from ..project import FunctionInfo, Project, as_project, attr_chain

_LOG_MUTATORS = frozenset({"append", "extend", "insert", "pop", "remove", "clear"})
_COORD_STATE = frozenset({"_dpm", "_frozen", "_deferred", "control_log"})
_WRITERS = ("__init__", "apply")
# replicated control plane: modules where coordinator.apply itself is
# leader-only (follower code replays; see module docstring)
_REPLICATED_MODULES = frozenset({"repro.etl.replication", "repro.etl.transport"})
_APPLY_CALLS = frozenset({"apply", "apply_update"})
_LEADER_CLASSES = frozenset({"LeaderNode"})


def _coordinator_receiver(chain: Optional[str], coord_names: Set[str]) -> bool:
    """Is this dotted receiver chain coordinator-typed?"""
    if chain is None:
        return False
    root = chain.split(".")[0]
    leaf = chain.split(".")[-1]
    if leaf in ("coordinator", "coord") or leaf.endswith("_coordinator") or leaf.endswith("_coord"):
        return True
    return chain == root and root in coord_names


@register
class SingleWriterControl(Rule):
    id = "single-writer-control"
    title = "only StateCoordinator.apply appends control_log / mutates coordinator state"
    motivation = (
        "PR 5's control_log is the replication primitive: a write outside "
        "the single writer is unlogged history, and every instance "
        "reconstructing state from the log silently diverges"
    )

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        project = as_project(ctxs)
        writer_qnames = {
            info.qname
            for info in project.functions.values()
            if info.cls == "StateCoordinator" and info.name in _WRITERS
        }
        apply_qnames = {q for q in writer_qnames if q.endswith(".apply")}
        for info in project.functions.values():
            if info.qname in writer_qnames:
                continue
            if apply_qnames and any(
                project.only_called_from(info.qname, a) for a in apply_qnames
            ):
                # a private step of apply: every caller chain ends at apply
                continue
            yield from self._check_fn(project, info)
            if (
                info.module.name in _REPLICATED_MODULES
                and info.cls not in _LEADER_CLASSES
            ):
                yield from self._check_replica_apply(info)

    def _check_replica_apply(self, info: FunctionInfo) -> Iterator[Finding]:
        """Inside the replication modules only LeaderNode may call
        ``coordinator.apply``; everything else replays."""
        ctx = info.ctx
        where = f"{info.cls + '.' if info.cls else ''}{info.name}"
        coord_names = _bound_coordinators(info)
        for node in ast.walk(info.node):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _APPLY_CALLS
            ):
                continue
            if _coordinator_receiver(attr_chain(node.func.value), coord_names):
                recv = ctx.segment(node.func.value) or "<expr>"
                yield ctx.finding(
                    self.id,
                    node,
                    f"{recv}.{node.func.attr}() in {where}(): in the "
                    "replicated control plane only the leader path "
                    "(LeaderNode) may call StateCoordinator.apply; follower "
                    "code rebuilds state through replay_control_log(..., "
                    "coordinator=...) so every write ships on the log",
                )

    def _check_fn(self, project: Project, info: FunctionInfo) -> Iterator[Finding]:
        ctx = info.ctx
        where = f"{info.cls + '.' if info.cls else ''}{info.name}"
        coord_names = _bound_coordinators(info)

        for node in ast.walk(info.node):
            # coordinator.control_log.append(...) -- any receiver: the
            # attribute name IS the contract
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _LOG_MUTATORS
                and (
                    (
                        isinstance(node.func.value, ast.Attribute)
                        and node.func.value.attr == "control_log"
                    )
                    or (
                        isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "control_log"
                    )
                )
            ):
                yield ctx.finding(
                    self.id,
                    node,
                    f"control_log.{node.func.attr}() in {where}(): only "
                    "StateCoordinator.apply may write the control log -- "
                    "route the event through coordinator.apply(event) so it "
                    "is recorded for replay",
                )
                continue
            targets: List[Tuple[ast.expr, str]] = []
            if isinstance(node, ast.Assign):
                targets = [(t, "assignment") for t in _flat_targets(node.targets)]
            elif isinstance(node, ast.AugAssign):
                targets = [(node.target, "augmented assignment")]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [(node.target, "assignment")]
            for tgt, what in targets:
                if not (isinstance(tgt, ast.Attribute) and tgt.attr in _COORD_STATE):
                    continue
                if tgt.attr == "control_log":
                    # rebinding the log itself rewrites history: flagged on
                    # any receiver, like the mutator calls above
                    pass
                elif not _coordinator_receiver(attr_chain(tgt.value), coord_names):
                    continue
                recv = ctx.segment(tgt.value) or "<expr>"
                yield ctx.finding(
                    self.id,
                    tgt,
                    f"{what} to {recv}.{tgt.attr} in {where}(): coordinator "
                    "state has one writer (StateCoordinator.apply); anything "
                    "else is unlogged history that breaks control-log replay",
                )


def _bound_coordinators(info: FunctionInfo) -> Set[str]:
    """Names bound from StateCoordinator(...) / replay_control_log(...) /
    from_dusb(...) in this function (plus ``self`` inside the class)."""
    coord_names: Set[str] = set()
    if info.cls == "StateCoordinator":
        coord_names.add("self")
    for node in ast.walk(info.node):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fchain = attr_chain(node.value.func) or ""
            tail = fchain.split(".")[-1]
            if tail in ("StateCoordinator", "replay_control_log", "from_dusb"):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        coord_names.add(tgt.id)
    return coord_names


def _flat_targets(targets: Sequence[ast.expr]) -> Iterator[ast.expr]:
    for t in targets:
        if isinstance(t, (ast.Tuple, ast.List)):
            yield from _flat_targets(t.elts)
        else:
            yield t
