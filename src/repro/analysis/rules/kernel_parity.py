"""kernel-ref-parity: every Pallas kernel has a pure-jnp twin in ref.py and
a parity test that exercises both.

The repo's correctness story for accelerator code is twin-based: each
kernel in ``kernels/`` (``pl.pallas_call`` users) ships a pure-``jnp``
reference implementation in ``kernels/ref.py``, and a test asserts the two
agree.  The twin is what makes a kernel reviewable (the ref IS the spec)
and what CI actually runs in interpret mode.  A kernel without a twin, or
a twin nothing compares against, is untested device code.

Project-level checks (this rule sees the whole file set at once):

  * every public top-level function in a ``pallas_call``-using module under
    a ``kernels/`` directory must have a ``<name>_ref`` twin in that
    directory's ``ref.py`` (aliases: ``flash_attention`` -> ``attention_ref``;
    ``<base>_shard`` variants are covered by ``<base>``'s twin);
  * some test file under the repo's ``tests/`` directory (located by
    walking up from the kernels dir) must reference BOTH the kernel name
    and its twin's name -- the onehot regression this encodes: a test that
    called ``onehot_map`` but compared against ``masked_gather_ref``,
    i.e. the twin existed and was never consulted.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterator, List, Sequence, Set

from ..core import FileCtx, Finding, Rule, register

ALIASES = {"flash_attention": "attention_ref"}

_SKIP_MODULES = {"ref.py", "__init__.py", "ops.py"}


def _top_level_defs(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in tree.body if isinstance(n, ast.FunctionDef)]


def _twin_name(kernel: str) -> str:
    base = kernel[: -len("_shard")] if kernel.endswith("_shard") else kernel
    return ALIASES.get(base, base + "_ref")


def _find_tests_dir(kernels_dir: Path) -> Path | None:
    for up in [kernels_dir, *kernels_dir.parents]:
        cand = up / "tests"
        if cand.is_dir():
            return cand
    return None


@register
class KernelRefParity(Rule):
    id = "kernel-ref-parity"
    title = "every Pallas kernel has a ref.py twin and a parity test using both"
    motivation = (
        "the ref twin is the kernel's spec and its only CI coverage; the "
        "onehot test compared against the WRONG twin for two PRs without "
        "anything noticing"
    )

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        by_dir: Dict[Path, List[FileCtx]] = {}
        for ctx in ctxs:
            if "kernels" not in ctx.path.parts:
                continue
            if ctx.path.name in _SKIP_MODULES:
                continue
            if "pallas_call" not in ctx.source:
                continue
            kdir = ctx.path.parent
            by_dir.setdefault(kdir, []).append(ctx)

        for kdir, kernel_ctxs in sorted(by_dir.items()):
            yield from self._check_dir(kdir, kernel_ctxs)

    def _check_dir(self, kdir: Path, kernel_ctxs: List[FileCtx]) -> Iterator[Finding]:
        ref_path = kdir / "ref.py"
        ref_names: Set[str] = set()
        if ref_path.is_file():
            try:
                ref_tree = ast.parse(ref_path.read_text())
                ref_names = {d.name for d in _top_level_defs(ref_tree)}
            except SyntaxError:
                pass  # surfaced as parse-error when ref.py is in the run

        tests_dir = _find_tests_dir(kdir)
        test_text = ""
        if tests_dir is not None:
            for t in sorted(tests_dir.rglob("test*.py")):
                try:
                    test_text += t.read_text() + "\n"
                except OSError:
                    continue

        for ctx in kernel_ctxs:
            for fn in _top_level_defs(ctx.tree):
                if fn.name.startswith("_"):
                    continue
                twin = _twin_name(fn.name)
                if not ref_path.is_file():
                    yield ctx.finding(
                        self.id,
                        fn,
                        f"kernel {fn.name}() has no {ref_path.name} next to "
                        "it; add a pure-jnp twin module",
                    )
                    continue
                if twin not in ref_names:
                    yield ctx.finding(
                        self.id,
                        fn,
                        f"kernel {fn.name}() has no twin {twin}() in "
                        f"{ref_path.name}; the ref implementation is the "
                        "kernel's spec and its interpret-mode CI coverage",
                    )
                    continue
                if fn.name.endswith("_shard"):
                    continue  # parity is asserted through the base kernel
                if tests_dir is None:
                    yield ctx.finding(
                        self.id,
                        fn,
                        f"no tests/ directory found above {kdir}; kernel "
                        f"{fn.name}() needs a parity test against {twin}()",
                    )
                    continue
                has_kernel = re.search(rf"\b{re.escape(fn.name)}\b", test_text)
                has_twin = re.search(rf"\b{re.escape(twin)}\b", test_text)
                if not (has_kernel and has_twin):
                    missing = (
                        f"{twin}()"
                        if has_kernel
                        else f"{fn.name}() and {twin}()"
                    )
                    yield ctx.finding(
                        self.id,
                        fn,
                        f"no test under {tests_dir.name}/ references "
                        f"{missing}; add a parity test asserting "
                        f"{fn.name}() matches {twin}()",
                    )
