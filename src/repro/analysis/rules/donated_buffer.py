"""donated-buffer-reuse: no read of a buffer after it was donated to a jit
program.

PR 6's device-densify path donates the packed columnar buffer into the
fused dispatch (``kernels/ops.py``: ``_columnar_program(...)`` /
``_columnar_sharded_program(...)`` are built with ``donate_argnums=(0,)``
on non-CPU backends) so XLA can reuse the input allocation for the
output.  After the call the donated array is DEAD -- but only on backends
that honour donation.  The CPU backend, which is what every test and the
whole of CI runs on, silently ignores ``donate_argnums``, so a read of
the donated buffer after the call returns the right answer in CI and
garbage (or a crash) on TPU/GPU.  That asymmetry is exactly the class of
bug a test suite cannot catch; this rule makes the *dataflow* the gate.

Mechanics (project model): functions RETURNING ``jax.jit(...,
donate_argnums=...)`` are donation factories; wrappers that feed a
parameter into a donated position of a factory's program donate that
parameter in turn (the fixpoint in
:meth:`repro.analysis.project.Project._build_donation_map` -- so
``ops.dmm_apply_columnar`` donates ``packed`` and the rule sees through
the import/alias at every call site).  Within each function the rule
records the dotted chain passed in each donated position
(``dense.packed``) and flags any later load of that chain -- or of a
longer chain it prefixes -- unless the root name was rebound in between.
Textual order approximates execution order; a donated read hidden by a
back-edge needs a reviewer, not a waiver.

Conditional donation (``donate_argnums=(0,) if donate else ()``) counts
as donating: the whole point is the configuration CI never exercises.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Sequence, Tuple

from ..core import FileCtx, Finding, Rule, register
from ..project import FunctionInfo, Project, as_project, attr_chain


def _pos(node: ast.AST) -> Tuple[int, int]:
    return (node.lineno, node.col_offset)


def _end(node: ast.AST) -> Tuple[int, int]:
    return (node.end_lineno or node.lineno, node.end_col_offset or 0)


@register
class DonatedBufferReuse(Rule):
    id = "donated-buffer-reuse"
    title = "no read of a buffer after it is donated to a jit program"
    motivation = (
        "donate_argnums is a no-op on the CPU CI backend: a reuse of the "
        "donated packed buffer passes every test we can run and corrupts "
        "on TPU/GPU (PR 6's device-densify contract)"
    )

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        project = as_project(ctxs)
        for info in project.functions.values():
            yield from self._check_fn(project, info)

    # -- per-function dataflow ------------------------------------------------
    def _check_fn(self, project: Project, info: FunctionInfo) -> Iterator[Finding]:
        module = info.module
        ctx = info.ctx

        # local names bound to a donating program: g = _columnar_program(...)
        # or g = jax.jit(f, donate_argnums=...) -- calling g(...) donates
        local_programs: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(info.node):
            if not (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)):
                continue
            # passing the whole Call to donated_positions asks "what would
            # calling its RESULT donate": factory(...) and
            # jax.jit(f, donate_argnums=...) both answer here
            positions = project.donated_positions(module, node.value)
            if positions:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        local_programs[tgt.id] = positions

        # donation events: (end position of the call, donated chain, callee)
        events: List[Tuple[Tuple[int, int], str, str]] = []
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            donated_args: List[ast.expr] = []
            callee = ""
            t = project.donating_function(module, node.func)
            if t is not None:
                callee = t.name
                for i, pname in sorted(t.donates.items()):
                    if i < len(node.args):
                        donated_args.append(node.args[i])
                    else:
                        for kw in node.keywords:
                            if kw.arg == pname:
                                donated_args.append(kw.value)
            else:
                positions: Tuple[int, ...] = ()
                if isinstance(node.func, ast.Name) and node.func.id in local_programs:
                    positions = local_programs[node.func.id]
                    callee = node.func.id
                else:
                    positions = project.donated_positions(module, node.func)
                    if positions:
                        callee = ctx.segment(node.func) or "<program>"
                for p in positions:
                    if p < len(node.args):
                        donated_args.append(node.args[p])
            for arg in donated_args:
                chain = attr_chain(arg)
                if chain is not None:
                    events.append((_end(node), chain, callee))
        if not events:
            return

        # rebinds of a root name kill its tracking from that line on
        rebinds: Dict[str, List[int]] = {}

        def bind(tgt: ast.expr, line: int) -> None:
            if isinstance(tgt, ast.Name):
                rebinds.setdefault(tgt.id, []).append(line)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    bind(el, line)

        for node in ast.walk(info.node):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    bind(tgt, node.lineno)
            elif isinstance(node, (ast.AnnAssign, ast.NamedExpr)):
                bind(node.target, node.lineno)
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                bind(node.target, node.lineno)
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    if item.optional_vars is not None:
                        bind(item.optional_vars, node.lineno)

        def rebound_between(root: str, lo: int, hi: int) -> bool:
            return any(lo <= ln <= hi for ln in rebinds.get(root, ()))

        # later loads of a donated chain (or anything it prefixes)
        for node in ast.walk(info.node):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            if not isinstance(getattr(node, "ctx", None), ast.Load):
                continue
            chain = attr_chain(node)
            if chain is None:
                continue
            for call_end, donated, callee in events:
                # exact-chain match only: a read of `packed.shape` contains
                # the load of `packed` as a subexpression, so the exact node
                # is always walked and longer chains never need their own
                # report
                if chain != donated:
                    continue
                if _pos(node) < call_end:
                    continue
                root = donated.split(".")[0]
                if rebound_between(root, call_end[0], node.lineno):
                    continue
                yield ctx.finding(
                    self.id,
                    node,
                    f"'{chain}' read after being donated to {callee}() in "
                    f"{info.name}() (donate_argnums): the buffer is dead on "
                    "TPU/GPU even though CPU CI keeps it alive -- recompute "
                    "it, use the program's output, or drop the donation",
                )
                break
