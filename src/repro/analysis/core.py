"""Analyzer framework: file contexts, rule registry, waivers, reports.

The moving parts:

  * :class:`FileCtx` -- one parsed source file (path, source, AST, waivers)
    plus package predicates (``in_package("repro", "etl")``) so rules can
    scope themselves to the packages that own an invariant;
  * :class:`Rule` -- one invariant.  Per-file rules implement
    :meth:`Rule.check_file`; cross-file rules (kernel/ref parity) implement
    :meth:`Rule.check_project` and run once over the whole file set;
  * the waiver machinery -- ``# metl: allow[rule-id] reason`` suppresses a
    finding on the same line, the line below a standalone waiver comment,
    or (when the comment sits on a ``def`` line) the whole function body.
    A waiver without a reason is itself a finding (``bad-waiver``): the
    reason is the reviewable artifact;
  * :func:`analyze` -- collect files, run rules, apply waivers, return a
    :class:`Report` (text/JSON rendering lives in :mod:`repro.analysis.cli`).

Rules register through :func:`register`; importing
:mod:`repro.analysis.rules` pulls in every built-in rule module.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "Waiver",
    "FileCtx",
    "Rule",
    "RULES",
    "register",
    "Report",
    "analyze",
    "collect_files",
]

WAIVER_RE = re.compile(r"#\s*metl:\s*allow\[([A-Za-z0-9_,\- ]+)\]\s*(.*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Waiver:
    """One inline ``# metl: allow[rule-id] reason`` comment.

    ``span`` is the inclusive line range the waiver suppresses: the comment
    line and the line below it, widened to the whole function body when the
    comment sits on a ``def`` line.
    """

    line: int
    rules: Tuple[str, ...]
    reason: str
    span: Tuple[int, int]

    def covers(self, rule: str, line: int) -> bool:
        return rule in self.rules and self.span[0] <= line <= self.span[1]


class FileCtx:
    """One parsed source file, shared by every rule."""

    def __init__(self, path: Path, rel: str, source: str, tree: ast.Module) -> None:
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.waivers: List[Waiver] = []
        # the owning repro.analysis.project.Module once a Project is built
        # over this file set (set by Project.__init__; None for a bare ctx)
        self.module: Optional[object] = None
        self._func_spans = _function_spans(tree)
        self._parse_waivers()

    # -- package predicates ---------------------------------------------------
    def in_package(self, *parts: str) -> bool:
        """True when ``parts`` appear as consecutive path components, e.g.
        ``ctx.in_package("repro", "etl")`` for src/repro/etl/engines.py."""
        p = self.path.parts
        n = len(parts)
        return any(p[i : i + n] == parts for i in range(len(p) - n + 1))

    # -- source access --------------------------------------------------------
    def segment(self, node: ast.AST) -> str:
        """The source text of a node ('' when unavailable)."""
        return ast.get_source_segment(self.source, node) or ""

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule=rule,
            path=self.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            message=message,
        )

    # -- waivers --------------------------------------------------------------
    def _parse_waivers(self) -> None:
        # real COMMENT tokens only -- a waiver example quoted in a docstring
        # is documentation, not a waiver
        try:
            tokens = list(
                tokenize.generate_tokens(io.StringIO(self.source).readline)
            )
        except (tokenize.TokenError, IndentationError):
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            i = tok.start[0]
            m = WAIVER_RE.search(tok.string)
            if not m:
                continue
            rules = tuple(
                r.strip() for r in m.group(1).split(",") if r.strip()
            )
            reason = m.group(2).strip()
            span = self._func_spans.get(i, (i, i + 1))
            self.waivers.append(
                Waiver(line=i, rules=rules, reason=reason, span=span)
            )

    def waived(self, f: Finding) -> Optional[Waiver]:
        for w in self.waivers:
            if w.covers(f.rule, f.line):
                return w
        return None


def _function_spans(tree: ast.Module) -> Dict[int, Tuple[int, int]]:
    """def-line -> (first body line incl. decorators, last line) for every
    function, so a waiver on a ``def`` covers the whole body."""
    spans: Dict[int, Tuple[int, int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            start = min(
                [node.lineno] + [d.lineno for d in node.decorator_list]
            )
            spans[node.lineno] = (start, node.end_lineno or node.lineno)
    return spans


# -- rule registry ------------------------------------------------------------


class Rule:
    """One static invariant.

    Subclasses set ``id`` (the waiver/--select key), ``title`` and
    ``motivation`` (the PR/regression that made the invariant worth a
    gate), and implement :meth:`check_file` and/or :meth:`check_project`.
    """

    id: str = ""
    title: str = ""
    motivation: str = ""

    def check_file(self, ctx: FileCtx) -> Iterator[Finding]:
        return iter(())

    def check_project(self, ctxs: Sequence[FileCtx]) -> Iterator[Finding]:
        return iter(())


RULES: Dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator: instantiate and register a :class:`Rule`."""
    rule = cls()
    if not rule.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    RULES[rule.id] = rule
    return cls


# -- the run ------------------------------------------------------------------


@dataclasses.dataclass
class Report:
    """The outcome of one analyzer run."""

    paths: List[str]
    rules: List[str]
    n_files: int
    findings: List[Finding]
    waived: List[Tuple[Finding, Waiver]]

    @property
    def ok(self) -> bool:
        return not self.findings

    def as_dict(self) -> Dict[str, object]:
        counts: Dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "version": 1,
            "ok": self.ok,
            "paths": self.paths,
            "rules": self.rules,
            "n_files": self.n_files,
            "counts": counts,
            "findings": [f.as_dict() for f in self.findings],
            "waived": [
                {**f.as_dict(), "reason": w.reason, "waiver_line": w.line}
                for f, w in self.waived
            ],
        }


def collect_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories to the sorted set of .py files under them."""
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(
                f
                for f in sorted(path.rglob("*.py"))
                if "__pycache__" not in f.parts
            )
        elif path.suffix == ".py":
            out.append(path)
    seen = set()
    uniq = []
    for f in out:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def _load(path: Path) -> Tuple[Optional[FileCtx], Optional[Finding]]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError) as e:
        line = getattr(e, "lineno", 1) or 1
        return None, Finding(
            rule="parse-error",
            path=str(path),
            line=line,
            col=1,
            message=f"{type(e).__name__}: {e}",
        )
    return FileCtx(path, str(path), source, tree), None


def _selected(
    select: Optional[Sequence[str]], ignore: Optional[Sequence[str]]
) -> List[str]:
    ids = list(RULES)
    if select:
        unknown = sorted(set(select) - set(ids))
        if unknown:
            raise ValueError(f"unknown rule id(s): {', '.join(unknown)}")
        ids = [r for r in ids if r in set(select)]
    if ignore:
        unknown = sorted(set(ignore) - set(RULES))
        if unknown:
            raise ValueError(f"unknown rule id(s): {', '.join(unknown)}")
        ids = [r for r in ids if r not in set(ignore)]
    return ids


def analyze(
    paths: Sequence[str],
    *,
    select: Optional[Sequence[str]] = None,
    ignore: Optional[Sequence[str]] = None,
) -> Report:
    """Run the (selected) rule set over ``paths``; apply waivers."""
    from . import rules as _rules  # noqa: F401  (imports register built-ins)

    rule_ids = _selected(select, ignore)
    active = [RULES[r] for r in rule_ids]

    ctxs: List[FileCtx] = []
    raw: List[Finding] = []
    for path in collect_files(paths):
        ctx, err = _load(path)
        if err is not None:
            raw.append(err)
            continue
        ctxs.append(ctx)
        if "bad-waiver" not in rule_ids:
            continue
        for w in ctx.waivers:
            if not w.reason:
                raw.append(
                    Finding(
                        rule="bad-waiver",
                        path=ctx.rel,
                        line=w.line,
                        col=1,
                        message=(
                            "waiver without a reason: write "
                            "'# metl: allow[rule-id] why it is safe'"
                        ),
                    )
                )
            for r in w.rules:
                if r not in RULES:
                    raw.append(
                        Finding(
                            rule="bad-waiver",
                            path=ctx.rel,
                            line=w.line,
                            col=1,
                            message=f"waiver names unknown rule {r!r}",
                        )
                    )

    # the whole-program model, built ONCE per run; rules receive it as their
    # check_project argument (it is Sequence[FileCtx]-compatible) and every
    # ctx gets its .module set for import-aware per-file rules
    from .project import Project

    project = Project(ctxs)

    by_rel = {ctx.rel: ctx for ctx in ctxs}
    for rule in active:
        for ctx in ctxs:
            raw.extend(rule.check_file(ctx))
        raw.extend(rule.check_project(project))

    # bad-waiver (and the post-hoc unused-waiver below) are unwaivable: the
    # waiver machinery can't excuse its own misuse
    _UNWAIVABLE = {"bad-waiver", "unused-waiver"}
    findings: List[Finding] = []
    waived: List[Tuple[Finding, Waiver]] = []
    used_waivers: set = set()
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.rule)):
        ctx = by_rel.get(f.path)
        if ctx is not None and f.rule not in _UNWAIVABLE:
            # usage is any-cover: a waiver "suppresses something" when any
            # raw finding falls in its span, even if an earlier overlapping
            # waiver claimed the finding first
            for w in ctx.waivers:
                if w.covers(f.rule, f.line):
                    used_waivers.add((ctx.rel, w.line, w.rules))
        w = ctx.waived(f) if ctx is not None and f.rule not in _UNWAIVABLE else None
        if w is not None:
            waived.append((f, w))
        else:
            findings.append(f)

    if "unused-waiver" in rule_ids:
        selected = set(rule_ids)
        for ctx in ctxs:
            for w in ctx.waivers:
                if not w.reason or any(r not in RULES for r in w.rules):
                    continue  # already a bad-waiver finding
                if not set(w.rules) <= selected:
                    continue  # a named rule didn't run: can't judge usage
                if (ctx.rel, w.line, w.rules) in used_waivers:
                    continue
                findings.append(
                    Finding(
                        rule="unused-waiver",
                        path=ctx.rel,
                        line=w.line,
                        col=1,
                        message=(
                            f"waiver for {', '.join(w.rules)} suppresses "
                            "nothing -- the code it excused is gone; "
                            "delete the comment"
                        ),
                    )
                )
        findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    return Report(
        paths=list(paths),
        rules=rule_ids,
        n_files=len(ctxs),
        findings=findings,
        waived=waived,
    )
